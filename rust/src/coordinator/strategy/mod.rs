//! The open strategy layer of the training API.
//!
//! The paper's central observation is that accuracy hinges on *which*
//! combine mechanism and communication graph a run uses (Observations
//! 2–3, Ada §4). This module makes that axis **open**: a per-iteration
//! [`CombineStrategy`] (how replicas compute and exchange updates), a
//! [`TopologyPolicy`] (which graph they exchange over, with its own
//! name-keyed registry in `crate::topology::registry`), and a
//! name-keyed [`Registry`] that constructs both, so new scenarios —
//! local SGD with periodic averaging, new compression schemes — plug in
//! without touching the session loop or this crate at all. The
//! compressed/variance-corrected family (`compressed_gossip`, `d2`,
//! `consensus_gossip` — see [`crate::compress`]) is built this way:
//! three [`CombineStrategy`] implementations registered below, zero
//! session-loop changes.
//!
//! ## Shape of an iteration
//!
//! [`crate::coordinator::TrainSession`] drives every iteration through
//! two strategy calls with the DBench instrumentation point between
//! them (§3.1.2's *pre-averaging* metric capture):
//!
//! ```text
//! loss = strategy.local_phase(ctx, replicas)    // compute at θ_t
//! (variance capture — observers see θ before averaging)
//! (deg, bytes) = strategy.combine_phase(ctx, replicas)
//! ```
//!
//! With `TrainConfig::pipeline` set (and a strategy whose
//! `supports_pipeline()` says yes) the session calls the
//! `*_phase_bucket` pair instead: the local phase overlaps compute with
//! the combine's bucketed communication on the pool
//! ([`crate::exec::pipeline`]), the mixed result waits in the engine's
//! scratch across the capture point, and the combine phase publishes
//! it. Both routes are bit-identical by contract.
//!
//! The built-in strategies are the three execution paths the old
//! `Trainer` hard-wired:
//!
//! * [`CentralizedAverage`] — `C_complete`: global gradient averaging
//!   with one shared momentum buffer (the PyTorch-DDP baseline). The
//!   whole update runs in the local phase, so the capture point sees
//!   globally consistent replicas — exactly the old behaviour.
//! * [`GossipCombine`] — adapt-then-combine: per-worker fused local
//!   step, then a gossip round over the epoch's graph.
//! * [`FusedGossipCombine`] — combine-then-adapt (D-PSGD order):
//!   gradients at θ_t in the local phase, then the fused gossip+SGD
//!   kernel ([`crate::gossip::GossipEngine::mix_step`]).
//!
//! ## Registry
//!
//! [`registry()`] returns the builtin name → constructor table (every
//! [`SgdFlavor`] name plus its CLI alias). `SgdFlavor` itself is now a
//! thin facade whose `schedule()` resolves through this registry, and
//! [`crate::dbench::SessionPlan`] resolves its cells against a registry
//! the caller can extend — see `examples/custom_strategy.rs` for a
//! complete out-of-crate strategy registered and trained end-to-end.
//!
//! [`SgdFlavor`]: crate::coordinator::SgdFlavor
//! [`TopologyPolicy`]: crate::topology::TopologyPolicy

mod centralized;
mod gossip;

pub use centralized::CentralizedAverage;
pub use gossip::{FusedGossipCombine, GossipCombine};

use crate::compress::{Codec, CompressedGossip, ConsensusGossip, D2Combine};
use crate::coordinator::LocalModel;
use crate::data::{Dataset, ShardLoader};
use crate::error::{AdaError, Result};
use crate::gossip::GossipEngine;
use crate::graph::{CommGraph, GraphKind};
use crate::util::matrix::ReplicaMatrix;
use crate::util::params::ParamTable;
use crate::topology::{
    AdaSchedule, OnePeerExponential, StaticSchedule, TopologyPolicy, VarianceAdaptive,
};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

/// Everything one strategy phase may touch, borrowed from the session
/// for exactly one call. Splitting the borrows out per call is what
/// lets strategies be plain trait objects with their own state.
pub struct StepCtx<'a> {
    /// The model driving per-worker compute.
    pub model: &'a mut dyn LocalModel,
    /// The training dataset.
    pub dataset: &'a dyn Dataset,
    /// Per-worker shard loaders (deterministic batch order).
    pub loaders: &'a [ShardLoader],
    /// The run's gossip engine (owns the persistent exec pool).
    pub engine: &'a mut GossipEngine,
    /// This epoch's communication graph; `None` for centralized runs.
    pub graph: Option<&'a CommGraph>,
    /// Failure-injection mask for this round (`None` = all present).
    /// Drawn by the session so the RNG stream stays with the run seed.
    pub active: Option<&'a [bool]>,
    /// `Some(bound)` routes the combine through the bounded-staleness
    /// path ([`crate::gossip::GossipEngine::mix_stale`] against the
    /// stale buffer the session ingests each round); `None` (the
    /// default outside fault-injection runs) keeps the live-row
    /// kernels.
    pub staleness: Option<usize>,
    /// 0-based epoch.
    pub epoch: usize,
    /// 0-based batch index within the epoch.
    pub batch: usize,
    /// Learning rate in effect.
    pub lr: f32,
    /// Worker count.
    pub n: usize,
    /// Flat parameter count per replica.
    pub param_count: usize,
}

/// One SGD scenario's per-iteration behaviour: how the `n` replicas
/// compute local updates and how they combine them.
///
/// Implementations hold their own cross-iteration state (momentum
/// buffers, gradient stashes, sync counters); [`CombineStrategy::prepare`]
/// sizes it once per run. Both phases must be deterministic functions
/// of `(ctx, replicas, internal state)` — the whole determinism story
/// of the execution engine (`crate::exec`) carries through the strategy
/// layer unchanged.
pub trait CombineStrategy: Send {
    /// Diagnostic name (not the run label — that comes from the
    /// [`StrategyInstance`]).
    fn name(&self) -> &str;

    /// Size per-run state for `n` workers × `p` parameters. Called once
    /// before the first iteration (and again from a fresh instance on
    /// resume — momentum restarts at zero, matching the models'
    /// internal buffers).
    fn prepare(&mut self, _n: usize, _p: usize) -> Result<()> {
        Ok(())
    }

    /// Local compute at θ_t for every worker; returns the mean training
    /// loss across replicas. Runs *before* the pre-averaging metric
    /// capture. Per-worker parameters are rows of the flat
    /// [`ReplicaMatrix`] ([`ReplicaMatrix::row_mut`]).
    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64>;

    /// The combine/update step, *after* the capture point. Returns
    /// `(graph degree, bytes sent per node)` for the iteration record.
    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)>;

    /// Whether this strategy implements the bucketed overlapped
    /// pipeline. The session takes the pipelined route only when
    /// `TrainConfig::pipeline` is set *and* this returns `true`;
    /// strategies that stay phase-ordered need not change.
    fn supports_pipeline(&self) -> bool {
        false
    }

    /// Pipelined local phase: run the per-replica compute on the
    /// calling thread while the combine's bucket consumers mix finished
    /// rows on the pool ([`crate::exec::pipeline::run_overlapped`]).
    /// The mixed result must stay unpublished (in the engine's scratch)
    /// so the capture point between the two phases still observes
    /// pre-averaging replicas; [`CombineStrategy::combine_phase_bucket`]
    /// publishes it. Must be **bit-identical** to
    /// [`CombineStrategy::local_phase`] + [`CombineStrategy::combine_phase`]
    /// at any thread count and bucket size. The default falls back to
    /// the phase-ordered `local_phase`.
    fn local_phase_bucket(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        self.local_phase(ctx, replicas)
    }

    /// Pipelined combine phase: publish the round the overlapped local
    /// phase already mixed (for the gossip strategies, one scratch
    /// swap). The default falls back to the phase-ordered
    /// [`CombineStrategy::combine_phase`], which is correct whenever
    /// `local_phase_bucket` fell back too.
    fn combine_phase_bucket(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        self.combine_phase(ctx, replicas)
    }
}

/// The tunable knobs a registry constructor may consume — the union of
/// the parameters the [`crate::coordinator::SgdFlavor`] variants carry,
/// with the CLI defaults — plus an [`extra`](StrategyParams::extra)
/// table for strategy-specific keys the flat fields don't name.
#[derive(Clone, PartialEq)]
pub struct StrategyParams {
    /// Training scale (graph nodes).
    pub n_workers: usize,
    /// Initial coordination number for the adaptive schedules.
    pub k0: Option<usize>,
    /// Ada's per-epoch decay of `k`.
    pub gamma_k: f64,
    /// `k` decrement per trigger (variance-adaptive).
    pub step: usize,
    /// Gini threshold (variance-adaptive).
    pub threshold: f64,
    /// Consecutive epochs below threshold before decaying.
    pub patience: usize,
    /// Strategy-specific keys (`codec`, `k`, `target`, `max_rounds`)
    /// passed through verbatim; each constructor `expect_only`s its own
    /// subset, so typos stay loud.
    pub extra: ParamTable,
}

/// Hand-written so the `extra` table is printed **only when non-empty**:
/// `{:?}` of a `StrategyRef::Named`'s params is part of the
/// [`crate::dbench::fingerprint`] resume-cache key, and pre-existing
/// cells (whose params have no extra keys) must keep their exact
/// pre-`extra` key text. The field order and format below match what
/// `#[derive(Debug)]` produced before the field existed.
impl fmt::Debug for StrategyParams {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut d = f.debug_struct("StrategyParams");
        d.field("n_workers", &self.n_workers)
            .field("k0", &self.k0)
            .field("gamma_k", &self.gamma_k)
            .field("step", &self.step)
            .field("threshold", &self.threshold)
            .field("patience", &self.patience);
        if !self.extra.is_empty() {
            d.field("extra", &self.extra);
        }
        d.finish()
    }
}

impl StrategyParams {
    /// Defaults at scale `n` (matching the `ada`/`dbench` CLI).
    pub fn for_n(n: usize) -> Self {
        StrategyParams {
            n_workers: n,
            k0: None,
            gamma_k: 1.0,
            step: 2,
            threshold: 0.002,
            patience: 1,
            extra: ParamTable::new(),
        }
    }

    fn need_k0(&self, name: &str) -> Result<usize> {
        self.k0.ok_or_else(|| {
            AdaError::Config(format!("strategy {name} needs k0 (initial coordination number)"))
        })
    }

    /// Build params from a [`ParamTable`] — the shape behind spec TOML
    /// `[strategy.<name>]` sections and CLI `name:k=v,…` arguments
    /// (shared with the topology registry). Unknown keys error.
    pub fn from_table(n: usize, table: &ParamTable) -> Result<Self> {
        table.expect_only(&[
            "k0",
            "gamma_k",
            "step",
            "threshold",
            "patience",
            "codec",
            "k",
            "target",
            "max_rounds",
        ])?;
        let mut p = Self::for_n(n);
        if let Some(v) = table.get_usize("k0")? {
            p.k0 = Some(v);
        }
        p.gamma_k = table.f64_or("gamma_k", p.gamma_k)?;
        p.step = table.usize_or("step", p.step)?;
        p.threshold = table.f64_or("threshold", p.threshold)?;
        p.patience = table.usize_or("patience", p.patience)?;
        for key in ["codec", "k", "target", "max_rounds"] {
            if let Some(v) = table.get(key) {
                p.extra = std::mem::take(&mut p.extra).set(key, v.clone());
            }
        }
        Ok(p)
    }
}

/// A fully resolved, ready-to-train scenario: what a [`Registry`]
/// constructor returns and what
/// [`crate::coordinator::SessionBuilder::strategy`] consumes.
pub struct StrategyInstance {
    /// Run label (paper-style: `C_complete`, `D_ring`, …) used in
    /// records, tables and summaries.
    pub label: String,
    /// Communication-graph policy; `None` = centralized.
    pub schedule: Option<Box<dyn TopologyPolicy>>,
    /// Neighbor count `k` for Table 2's LR scaling
    /// (`s = batch·(k+1)/divisor`): the densest phase of adaptive
    /// schedules sets the safe LR.
    pub k_neighbors: usize,
    /// The per-iteration combine step; `None` lets the session pick its
    /// default (centralized averaging without a schedule, split or
    /// fused gossip per `TrainConfig::fused` with one).
    pub combine: Option<Box<dyn CombineStrategy>>,
}

/// A registry constructor: build a [`StrategyInstance`] from params.
pub type StrategyCtor = Arc<dyn Fn(&StrategyParams) -> Result<StrategyInstance> + Send + Sync>;

/// Name → constructor table for training strategies. Starts from the
/// builtin [`registry()`] and is extensible at runtime — registering a
/// new scenario requires no change to `coordinator/` source.
pub struct Registry {
    entries: BTreeMap<String, StrategyCtor>,
}

impl Registry {
    /// An empty registry (no builtins).
    pub fn empty() -> Self {
        Registry { entries: BTreeMap::new() }
    }

    /// Register `ctor` under `name`, replacing any previous entry.
    pub fn register<F>(&mut self, name: impl Into<String>, ctor: F)
    where
        F: Fn(&StrategyParams) -> Result<StrategyInstance> + Send + Sync + 'static,
    {
        self.entries.insert(name.into(), Arc::new(ctor));
    }

    /// Register `alias` as another name for the existing `name`.
    pub fn alias(&mut self, alias: impl Into<String>, name: &str) -> Result<()> {
        let ctor = self
            .entries
            .get(name)
            .cloned()
            .ok_or_else(|| AdaError::Config(format!("cannot alias unknown strategy {name:?}")))?;
        self.entries.insert(alias.into(), ctor);
        Ok(())
    }

    /// Whether `name` resolves.
    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// All registered names (canonical names and aliases), sorted.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Construct the instance registered under `name`.
    pub fn resolve(&self, name: &str, params: &StrategyParams) -> Result<StrategyInstance> {
        let ctor = self.entries.get(name).ok_or_else(|| {
            AdaError::Config(format!(
                "unknown strategy {name:?} (registered: {})",
                self.names().join(", ")
            ))
        })?;
        ctor(params)
    }
}

/// Neighbor count of the exponential graph: ⌊log2(n−1)⌋+1.
fn k_exponential(n: usize) -> usize {
    ((n.saturating_sub(1)) as f64).log2().floor() as usize + 1
}

fn static_instance(
    label: &str,
    kind: GraphKind,
    k: usize,
    n: usize,
) -> Result<StrategyInstance> {
    Ok(StrategyInstance {
        label: label.to_string(),
        schedule: Some(Box::new(StaticSchedule::new(kind, n)?)),
        k_neighbors: k,
        combine: None,
    })
}

/// The builtin strategy table: every [`crate::coordinator::SgdFlavor`]
/// name (the §3.1.2 five, Ada, and the extension schedules) under its
/// paper-style name plus its CLI alias. Callers extend the returned
/// registry with their own scenarios and hand it to
/// [`crate::dbench::SessionPlan`].
pub fn registry() -> Registry {
    let mut reg = Registry::empty();
    reg.register("C_complete", |p: &StrategyParams| {
        Ok(StrategyInstance {
            label: "C_complete".into(),
            schedule: None,
            k_neighbors: p.n_workers.saturating_sub(1),
            combine: None,
        })
    });
    reg.register("D_complete", |p: &StrategyParams| {
        static_instance(
            "D_complete",
            GraphKind::Complete,
            p.n_workers.saturating_sub(1),
            p.n_workers,
        )
    });
    reg.register("D_ring", |p: &StrategyParams| {
        static_instance("D_ring", GraphKind::Ring, 2, p.n_workers)
    });
    reg.register("D_torus", |p: &StrategyParams| {
        static_instance("D_torus", GraphKind::Torus, 4, p.n_workers)
    });
    reg.register("D_exponential", |p: &StrategyParams| {
        static_instance(
            "D_exponential",
            GraphKind::Exponential,
            k_exponential(p.n_workers),
            p.n_workers,
        )
    });
    reg.register("D_adaptive", |p: &StrategyParams| {
        let k0 = p.need_k0("D_adaptive")?;
        Ok(StrategyInstance {
            label: "D_adaptive".into(),
            schedule: Some(Box::new(AdaSchedule::new(p.n_workers, k0, p.gamma_k))),
            k_neighbors: k0,
            combine: None,
        })
    });
    reg.register("D_one_peer", |p: &StrategyParams| {
        Ok(StrategyInstance {
            label: "D_one_peer".into(),
            schedule: Some(Box::new(OnePeerExponential::new(p.n_workers)?)),
            k_neighbors: 1,
            combine: None,
        })
    });
    reg.register("D_var_adaptive", |p: &StrategyParams| {
        let k0 = p.need_k0("D_var_adaptive")?;
        Ok(StrategyInstance {
            label: "D_var_adaptive".into(),
            schedule: Some(Box::new(VarianceAdaptive::new(
                p.n_workers,
                k0,
                p.step,
                p.threshold,
                p.patience,
            ))),
            k_neighbors: k0,
            combine: None,
        })
    });
    // The compressed / variance-corrected family (`crate::compress`).
    // All three default to the exponential graph — the densest of the
    // paper's sparse five — and accept the usual per-cell topology
    // override; their specific knobs travel in `params.extra`.
    reg.register("compressed_gossip", |p: &StrategyParams| {
        p.extra.expect_only(&["codec", "k"])?;
        let codec = Codec::parse(p.extra.get_str("codec")?.unwrap_or("bf16"))?;
        let k = p.extra.get_usize("k")?;
        let label = match k {
            Some(k) => format!("compressed_gossip[{},k={k}]", codec.name()),
            None => format!("compressed_gossip[{}]", codec.name()),
        };
        Ok(StrategyInstance {
            label,
            schedule: Some(Box::new(StaticSchedule::new(
                GraphKind::Exponential,
                p.n_workers,
            )?)),
            k_neighbors: k_exponential(p.n_workers),
            combine: Some(Box::new(CompressedGossip::new(codec, k))),
        })
    });
    reg.register("d2", |p: &StrategyParams| {
        p.extra.expect_only(&[])?;
        Ok(StrategyInstance {
            label: "d2".into(),
            schedule: Some(Box::new(StaticSchedule::new(
                GraphKind::Exponential,
                p.n_workers,
            )?)),
            k_neighbors: k_exponential(p.n_workers),
            combine: Some(Box::new(D2Combine::new())),
        })
    });
    reg.register("consensus_gossip", |p: &StrategyParams| {
        p.extra.expect_only(&["target", "max_rounds"])?;
        let target = p.extra.f64_or("target", 0.0)?;
        let max_rounds = p.extra.usize_or("max_rounds", 4)?;
        Ok(StrategyInstance {
            label: "consensus_gossip".into(),
            schedule: Some(Box::new(StaticSchedule::new(
                GraphKind::Exponential,
                p.n_workers,
            )?)),
            k_neighbors: k_exponential(p.n_workers),
            combine: Some(Box::new(ConsensusGossip::new(target, max_rounds))),
        })
    });
    for (alias, name) in [
        ("c_complete", "C_complete"),
        ("d_complete", "D_complete"),
        ("d_ring", "D_ring"),
        ("d_torus", "D_torus"),
        ("d_exponential", "D_exponential"),
        ("ada", "D_adaptive"),
        ("one_peer", "D_one_peer"),
        ("var_adaptive", "D_var_adaptive"),
    ] {
        reg.alias(alias, name).expect("builtin alias target exists");
    }
    reg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_resolves_every_flavor_name() {
        let reg = registry();
        let mut params = StrategyParams::for_n(8);
        params.k0 = Some(4);
        for name in [
            "C_complete",
            "D_complete",
            "D_ring",
            "D_torus",
            "D_exponential",
            "D_adaptive",
            "D_one_peer",
            "D_var_adaptive",
        ] {
            let inst = reg.resolve(name, &params).unwrap_or_else(|e| {
                panic!("builtin {name} must resolve: {e}")
            });
            assert_eq!(inst.label, name);
            assert_eq!(inst.schedule.is_none(), name == "C_complete");
        }
    }

    #[test]
    fn aliases_resolve_to_same_labels() {
        let reg = registry();
        let mut params = StrategyParams::for_n(8);
        params.k0 = Some(4);
        for (alias, label) in [("c_complete", "C_complete"), ("ada", "D_adaptive")] {
            assert_eq!(reg.resolve(alias, &params).unwrap().label, label);
        }
    }

    #[test]
    fn adaptive_without_k0_is_an_error() {
        let reg = registry();
        let params = StrategyParams::for_n(8);
        assert!(reg.resolve("D_adaptive", &params).is_err());
        assert!(reg.resolve("D_var_adaptive", &params).is_err());
    }

    #[test]
    fn unknown_name_lists_registered() {
        let reg = registry();
        let err = reg
            .resolve("D_nope", &StrategyParams::for_n(4))
            .unwrap_err()
            .to_string();
        assert!(err.contains("D_nope"), "{err}");
        assert!(err.contains("D_ring"), "{err}");
    }

    #[test]
    fn custom_registration_and_override() {
        let mut reg = registry();
        reg.register("d_everyother", |p: &StrategyParams| {
            static_instance("d_everyother", GraphKind::Ring, 2, p.n_workers)
        });
        assert!(reg.contains("d_everyother"));
        let inst = reg.resolve("d_everyother", &StrategyParams::for_n(6)).unwrap();
        assert_eq!(inst.label, "d_everyother");
        // Overriding a builtin is allowed (last registration wins).
        reg.register("D_ring", |p: &StrategyParams| {
            static_instance("D_ring_override", GraphKind::Ring, 2, p.n_workers)
        });
        assert_eq!(
            reg.resolve("D_ring", &StrategyParams::for_n(6)).unwrap().label,
            "D_ring_override"
        );
    }

    #[test]
    fn params_from_table_map_known_keys_and_reject_typos() {
        let t = ParamTable::parse_kv("k0=10,gamma_k=0.5,step=3,threshold=0.01,patience=2")
            .unwrap();
        let p = StrategyParams::from_table(8, &t).unwrap();
        assert_eq!(p.n_workers, 8);
        assert_eq!(p.k0, Some(10));
        assert_eq!(p.gamma_k, 0.5);
        assert_eq!(p.step, 3);
        assert_eq!(p.threshold, 0.01);
        assert_eq!(p.patience, 2);
        // Empty table = defaults.
        let d = StrategyParams::from_table(8, &ParamTable::new()).unwrap();
        assert_eq!(d, StrategyParams::for_n(8));
        // Typos are loud.
        let bad = ParamTable::parse_kv("kO=10").unwrap();
        assert!(StrategyParams::from_table(8, &bad).is_err());
    }

    #[test]
    fn k_exponential_matches_formula() {
        assert_eq!(k_exponential(8), 2 + 1); // log2(7) = 2.8 → 2, +1
        assert_eq!(k_exponential(64), 5 + 1);
        assert_eq!(k_exponential(2), 1); // log2(1) = 0, +1
    }

    #[test]
    fn params_debug_is_stable_without_extra_keys() {
        // `{:?}` of StrategyParams feeds the resume-cache fingerprint:
        // params without extra keys must render exactly as the derived
        // Debug did before the `extra` field existed, so pre-existing
        // caches stay valid.
        let p = StrategyParams::for_n(8);
        assert_eq!(
            format!("{p:?}"),
            "StrategyParams { n_workers: 8, k0: None, gamma_k: 1.0, \
             step: 2, threshold: 0.002, patience: 1 }"
        );
        // Extra keys must show up (different config ⇒ different key).
        let t = ParamTable::parse_kv("codec=bf16").unwrap();
        let q = StrategyParams::from_table(8, &t).unwrap();
        let text = format!("{q:?}");
        assert!(text.contains("extra"), "{text}");
        assert!(text.contains("codec"), "{text}");
        assert_ne!(format!("{p:?}"), text);
    }

    #[test]
    fn from_table_routes_compress_keys_into_extra() {
        let t = ParamTable::parse_kv("codec=f16,k=1024,target=0.5,max_rounds=3").unwrap();
        let p = StrategyParams::from_table(16, &t).unwrap();
        assert_eq!(p.extra.get_str("codec").unwrap(), Some("f16"));
        assert_eq!(p.extra.get_usize("k").unwrap(), Some(1024));
        assert_eq!(p.extra.get_f64("target").unwrap(), Some(0.5));
        assert_eq!(p.extra.get_usize("max_rounds").unwrap(), Some(3));
        // The flat fields keep their defaults.
        assert_eq!(p.k0, None);
        assert_eq!(p.step, 2);
    }

    #[test]
    fn compressed_family_resolves_with_labels_and_combines() {
        let reg = registry();
        let p = StrategyParams::for_n(8);
        for (name, label) in [
            ("compressed_gossip", "compressed_gossip[bf16]"),
            ("d2", "d2"),
            ("consensus_gossip", "consensus_gossip"),
        ] {
            let inst = reg.resolve(name, &p).unwrap_or_else(|e| {
                panic!("builtin {name} must resolve: {e}")
            });
            assert_eq!(inst.label, label);
            assert!(inst.schedule.is_some(), "{name} is decentralized");
            assert!(inst.combine.is_some(), "{name} brings its own combine");
        }
        // Parameterized: codec + k reach the label.
        let t = ParamTable::parse_kv("codec=f16,k=100").unwrap();
        let p = StrategyParams::from_table(8, &t).unwrap();
        let inst = reg.resolve("compressed_gossip", &p).unwrap();
        assert_eq!(inst.label, "compressed_gossip[f16,k=100]");
    }

    #[test]
    fn compressed_family_rejects_wrong_extras() {
        let reg = registry();
        // A codec typo fails at parse.
        let t = ParamTable::parse_kv("codec=int8").unwrap();
        let p = StrategyParams::from_table(8, &t).unwrap();
        assert!(reg.resolve("compressed_gossip", &p).is_err());
        // d2 takes no extra keys at all.
        let t = ParamTable::parse_kv("codec=bf16").unwrap();
        let p = StrategyParams::from_table(8, &t).unwrap();
        assert!(reg.resolve("d2", &p).is_err());
        // consensus_gossip doesn't take a codec either.
        assert!(reg.resolve("consensus_gossip", &p).is_err());
    }
}
