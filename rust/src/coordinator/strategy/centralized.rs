//! `C_complete`'s combine strategy: centralized gradient averaging.

use super::{CombineStrategy, StepCtx};
use crate::error::Result;
use crate::optim::SgdState;
use crate::util::matrix::ReplicaMatrix;

/// Centralized gradient averaging with one shared momentum buffer (the
/// PyTorch-DDP baseline of §3.1.2): every iteration computes gradients
/// at θ_t on all workers, averages them, applies a single momentum step
/// and broadcasts, so replicas stay globally consistent.
///
/// The whole update runs in [`CombineStrategy::local_phase`] — the
/// pre-averaging capture point then observes the already-consistent
/// replicas, matching the closed enum path this was extracted from.
/// [`CombineStrategy::combine_phase`] only accounts the ring-allreduce
/// communication cost (`2(n−1)/n · 4P` bytes per node).
pub struct CentralizedAverage {
    momentum: f32,
    state: SgdState,
    grad_acc: Vec<f32>,
}

impl CentralizedAverage {
    /// New strategy with the shared buffer's momentum coefficient.
    pub fn new(momentum: f32) -> Self {
        CentralizedAverage {
            momentum,
            state: SgdState::new(0, momentum, 0.0),
            grad_acc: Vec::new(),
        }
    }
}

impl CombineStrategy for CentralizedAverage {
    fn name(&self) -> &str {
        "centralized_average"
    }

    fn prepare(&mut self, _n: usize, p: usize) -> Result<()> {
        self.state = SgdState::new(p, self.momentum, 0.0);
        self.grad_acc = vec![0.0f32; p];
        Ok(())
    }

    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        let n = ctx.n;
        for a in self.grad_acc.iter_mut() {
            *a = 0.0;
        }
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            let (loss, g) = ctx.model.loss_and_grad(replicas.row(w), &batch)?;
            loss_sum += loss as f64;
            for (a, &gi) in self.grad_acc.iter_mut().zip(&g) {
                *a += gi;
            }
        }
        let inv = 1.0 / n as f32;
        for a in self.grad_acc.iter_mut() {
            *a *= inv;
        }
        self.state.step(replicas.row_mut(0), &self.grad_acc, ctx.lr);
        replicas.broadcast_first_row();
        Ok(loss_sum / n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        _replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        // Ring allreduce of gradients: 2(n−1)/n · 4P bytes per node.
        let (n, p) = (ctx.n, ctx.param_count);
        Ok((n - 1, (2 * (n - 1) * 4 * p / n) as u64))
    }
}
