//! `exec::pipeline` — the overlapped producer/consumer scheduler behind
//! the bucketed gossip pipeline.
//!
//! [`run_overlapped`] generalizes [`ExecEngine::run_jobs`]'s fork-join
//! round into a one-producer / many-consumer software pipeline: the
//! *producer* runs on the calling thread (the per-replica local step of
//! a training iteration), the *consumers* — one per parameter bucket —
//! run on the engine's parked pool workers, and a shared [`Progress`]
//! frontier replaces the two global phase barriers: each consumer
//! blocks only until the replica rows *its* next output row needs have
//! been produced, then mixes that row's bucket while the producer is
//! still stepping later replicas.
//!
//! ## Determinism contract
//!
//! Bucket boundaries ([`BucketTable`]) are a fixed function of
//! `(p, bucket_elems)` — never of the thread count — and every consumer
//! computes its output elements with the same per-element float
//! sequence as the phase-ordered kernels (ascending fold in graph-row
//! order; see `crate::gossip`). Which worker executes a bucket, and how
//! far the producer has advanced when it does, are therefore pure
//! wall-clock facts: pipelined output is **bit-identical** to phased
//! output at any thread count and any bucket size — the `run_reduce`
//! discipline applied to the whole iteration. Enforced across thread
//! counts, kernels and bucket sizes in `rust/tests/exec_determinism.rs`.
//!
//! ## Liveness
//!
//! The producer never dispatches onto the pool, so a blocked consumer
//! can never starve the work it waits for. On *every* producer exit
//! path — normal return, early `Err`, panic — a floodgate guard opens
//! the frontier ([`Progress::open`]) *before* the fork-join barrier
//! waits, so consumers always run to completion and the barrier always
//! releases. A consumer panic is contained in its worker and re-raised
//! on the calling thread after the barrier, exactly like
//! [`ExecEngine::run_jobs`].
//!
//! ## Memory model
//!
//! Producer and consumers hand rows across threads through
//! [`Progress`]'s mutex: every `retire` happens-before the `wait_for`
//! it satisfies, so a consumer that waited for row `i` observes all of
//! the producer's writes to rows `< i`. Callers (the gossip engine)
//! keep the accesses disjoint-by-protocol: the producer writes only
//! rows it has not yet retired, consumers read only rows below the
//! frontier they waited for.

use super::pool::{run_caught, Latch, PanicSlot, Task, TaskGuard};
use super::{ExecEngine, WaitGuard};
use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex};

/// Default bucket width of the overlapped pipeline: 64 Ki f32 = 256 KB
/// per source row per bucket — wide enough that one bucket amortizes a
/// channel wake-up, narrow enough that several buckets are in flight on
/// one epoch-scale model (the decent-dp `bucket_size_in_mb` knob, here
/// in elements because the store is f32-only).
pub const DEFAULT_BUCKET_ELEMS: usize = 64 * 1024;

/// The fixed bucket descriptor table of one overlapped round: the
/// parameter axis `[0, p)` cut into contiguous `bucket_elems`-wide
/// column ranges (last one short). Depends on `(p, bucket_elems)`
/// **only** — never on the thread count — which is half of the
/// determinism contract (the other half is the per-element fold order
/// inside each bucket kernel).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketTable {
    p: usize,
    bucket_elems: usize,
    bounds: Vec<Range<usize>>,
}

impl BucketTable {
    /// Table for `p` columns at `bucket_elems` per bucket
    /// (`0` = [`DEFAULT_BUCKET_ELEMS`]).
    pub fn new(p: usize, bucket_elems: usize) -> Self {
        let bucket_elems = if bucket_elems == 0 {
            DEFAULT_BUCKET_ELEMS
        } else {
            bucket_elems
        };
        let mut bounds = Vec::with_capacity(p.div_ceil(bucket_elems));
        let mut start = 0;
        while start < p {
            let end = (start + bucket_elems).min(p);
            bounds.push(start..end);
            start = end;
        }
        BucketTable { p, bucket_elems, bounds }
    }

    /// Whether this table was built for exactly `(p, bucket_elems)` —
    /// the cache key the gossip engine uses to reuse the table across
    /// rounds instead of recomputing it per call.
    pub fn matches(&self, p: usize, bucket_elems: usize) -> bool {
        let bucket_elems = if bucket_elems == 0 {
            DEFAULT_BUCKET_ELEMS
        } else {
            bucket_elems
        };
        self.p == p && self.bucket_elems == bucket_elems
    }

    /// Columns covered (`[0, p)`).
    pub fn p(&self) -> usize {
        self.p
    }

    /// Resolved bucket width in elements.
    pub fn bucket_elems(&self) -> usize {
        self.bucket_elems
    }

    /// Bucket count.
    pub fn len(&self) -> usize {
        self.bounds.len()
    }

    /// True when `p == 0` (no buckets).
    pub fn is_empty(&self) -> bool {
        self.bounds.is_empty()
    }

    /// The bucket ranges, ascending and tiling `[0, p)` exactly.
    pub fn buckets(&self) -> &[Range<usize>] {
        &self.bounds
    }
}

/// The pipeline's produced-row frontier: "rows `[0, retired)` are
/// final". The producer advances it monotonically; consumers block on
/// it per output row. The mutex hand-off is also the happens-before
/// edge that publishes the producer's row writes to the consumer that
/// waited (see the module docs' memory-model note).
#[derive(Debug, Default)]
pub struct Progress {
    retired: Mutex<usize>,
    advanced: Condvar,
}

impl Progress {
    /// A frontier at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark rows `[0, upto)` produced. Monotonic: a smaller `upto`
    /// than already retired is a no-op, so the floodgate's
    /// [`Progress::open`] cannot be walked back.
    pub fn retire(&self, upto: usize) {
        let mut r = self.retired.lock().expect("progress lock");
        if upto > *r {
            *r = upto;
            self.advanced.notify_all();
        }
    }

    /// Open the frontier entirely (every `wait_for` returns
    /// immediately, now and forever). The producer-exit floodgate.
    pub fn open(&self) {
        self.retire(usize::MAX);
    }

    /// Block until at least `need` rows are retired.
    pub fn wait_for(&self, need: usize) {
        let mut r = self.retired.lock().expect("progress lock");
        while *r < need {
            r = self.advanced.wait(r).expect("progress wait");
        }
    }

    /// Current frontier (diagnostics/tests; racy by nature).
    pub fn retired(&self) -> usize {
        *self.retired.lock().expect("progress lock")
    }
}

/// Opens the frontier when dropped — the producer-exit floodgate that
/// guarantees consumer liveness on every exit path.
struct Floodgate<'a>(&'a Progress);

impl Drop for Floodgate<'_> {
    fn drop(&mut self) {
        self.0.open();
    }
}

/// Run one overlapped round: dispatch every `consumer` to the engine's
/// pool, then run `producer` on the calling thread; return the
/// producer's result once **all** consumers have finished (fork-join
/// barrier).
///
/// Consumers receive the shared [`Progress`] frontier and are expected
/// to `wait_for` the rows they read; the producer is expected to
/// `retire` rows as it finishes them (ascending). The frontier is
/// force-opened when the producer exits — normally, by `Err`, or by
/// panic — so consumers never hang on an unfinished producer.
///
/// Engines without a pool (serial, or a single thread) run the producer
/// to completion first and then every consumer inline in submission
/// order: all waits are satisfied trivially and the per-element float
/// sequences are unchanged, so `pipeline = true` is bit-identical (and
/// safe) at `threads = 1`.
pub fn run_overlapped<C, R>(
    engine: &ExecEngine,
    consumers: Vec<C>,
    producer: impl FnOnce(&Progress) -> R,
) -> R
where
    C: FnOnce(&Progress) + Send,
{
    let Some(pool) = engine.pool.as_deref().filter(|_| !consumers.is_empty()) else {
        // Serial path: produce everything, open the gate, then drain
        // the buckets in order on the calling thread.
        let progress = Progress::new();
        let result = producer(&progress);
        progress.open();
        for consumer in consumers {
            consumer(&progress);
        }
        return result;
    };

    let progress = Arc::new(Progress::new());
    let latch = Arc::new(Latch::new(consumers.len()));
    let panic_slot: PanicSlot = Arc::new(Mutex::new(None));
    let tasks: Vec<Task> = consumers
        .into_iter()
        .map(|job| {
            let guard = TaskGuard { latch: latch.clone() };
            let slot = panic_slot.clone();
            let prog = Arc::clone(&progress);
            let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                // Guard declared first so it drops last: the latch
                // counts down only after the job's borrows are dead.
                let _g = guard;
                run_caught(move || job(&prog), &slot);
            });
            // SAFETY: the WaitGuard below blocks until this task's
            // latch fires, on both the normal and the unwind path, so
            // every borrow captured in `job` outlives its use — the
            // same structured-concurrency argument as `run_jobs`.
            unsafe { super::erase_task(task) }
        })
        .collect();
    let result;
    {
        // Declaration order is load-bearing: guards drop in reverse,
        // so the floodgate opens the frontier BEFORE the barrier
        // waits — consumers blocked on an unfinished producer are
        // released instead of deadlocking the latch.
        let _barrier = WaitGuard(&latch);
        let _floodgate = Floodgate(&progress);
        pool.dispatch(tasks);
        result = producer(&progress);
    }
    if let Some(payload) = panic_slot.lock().expect("panic slot lock").take() {
        std::panic::resume_unwind(payload);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn bucket_table_tiles_exactly() {
        for (p, be) in [(10, 4), (4096, 4096), (4097, 4096), (1_000_000, 65_536), (5, 100)] {
            let t = BucketTable::new(p, be);
            assert_eq!(t.p(), p);
            assert!(!t.is_empty());
            assert_eq!(t.buckets().first().unwrap().start, 0);
            assert_eq!(t.buckets().last().unwrap().end, p);
            for w in t.buckets().windows(2) {
                assert_eq!(w[0].end, w[1].start, "buckets must tile");
            }
            for b in t.buckets() {
                assert!(b.end - b.start <= t.bucket_elems());
            }
            // Every bucket except the last is full-width.
            for b in &t.buckets()[..t.len() - 1] {
                assert_eq!(b.end - b.start, t.bucket_elems());
            }
        }
    }

    #[test]
    fn bucket_table_defaults_and_matches() {
        let t = BucketTable::new(1_000_000, 0);
        assert_eq!(t.bucket_elems(), DEFAULT_BUCKET_ELEMS);
        assert!(t.matches(1_000_000, 0));
        assert!(t.matches(1_000_000, DEFAULT_BUCKET_ELEMS));
        assert!(!t.matches(1_000_000, 4096));
        assert!(!t.matches(999_999, 0));
        assert!(BucketTable::new(0, 64).is_empty());
    }

    #[test]
    fn bucket_table_is_thread_count_independent() {
        // The whole point: the table is a pure function of (p, width).
        assert_eq!(BucketTable::new(12_345, 1000), BucketTable::new(12_345, 1000));
    }

    #[test]
    fn progress_is_monotone_and_open_is_final() {
        let p = Progress::new();
        assert_eq!(p.retired(), 0);
        p.retire(3);
        p.retire(1); // no-op
        assert_eq!(p.retired(), 3);
        p.open();
        p.retire(5); // cannot walk the floodgate back
        assert_eq!(p.retired(), usize::MAX);
        p.wait_for(usize::MAX); // returns immediately
    }

    fn sum_overlapped(engine: &ExecEngine, n: usize, buckets: usize) -> u64 {
        // Producer fills slot i then retires i+1; each consumer owns a
        // contiguous slice of slots and waits per slot — exercising the
        // frontier, not just the barrier.
        let data: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let total = AtomicU64::new(0);
        {
            let data = &data;
            let total = &total;
            let consumers: Vec<_> = super::super::partition(n, buckets, 1)
                .into_iter()
                .map(|r| {
                    move |progress: &Progress| {
                        let mut sum = 0u64;
                        for i in r {
                            progress.wait_for(i + 1);
                            sum += data[i].load(Ordering::Acquire);
                        }
                        total.fetch_add(sum, Ordering::SeqCst);
                    }
                })
                .collect();
            run_overlapped(engine, consumers, |progress: &Progress| {
                for (i, slot) in data.iter().enumerate() {
                    slot.store(i as u64 + 1, Ordering::Release);
                    progress.retire(i + 1);
                }
            });
        }
        total.load(Ordering::SeqCst)
    }

    #[test]
    fn overlapped_round_sees_every_produced_row() {
        let want = (1..=100u64).sum::<u64>();
        assert_eq!(sum_overlapped(&ExecEngine::serial(), 100, 7), want);
        assert_eq!(sum_overlapped(&ExecEngine::new(4), 100, 7), want);
        // More consumers than pool workers: they queue and still drain.
        assert_eq!(sum_overlapped(&ExecEngine::new(2), 100, 33), want);
    }

    #[test]
    fn producer_result_is_returned_and_consumers_all_ran() {
        let engine = ExecEngine::new(3);
        let hits = AtomicUsize::new(0);
        let out = {
            let hits = &hits;
            let consumers: Vec<_> = (0..5)
                .map(|_| {
                    move |_p: &Progress| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect();
            run_overlapped(&engine, consumers, |p: &Progress| {
                p.open();
                42u32
            })
        };
        assert_eq!(out, 42);
        assert_eq!(hits.load(Ordering::SeqCst), 5, "barrier covers all consumers");
    }

    #[test]
    fn early_producer_exit_releases_waiting_consumers() {
        // The producer returns (an Err-shaped early exit) without
        // retiring anything; the floodgate must still release every
        // consumer and the barrier must still hold.
        let engine = ExecEngine::new(2);
        let released = AtomicUsize::new(0);
        {
            let released = &released;
            let consumers: Vec<_> = (0..3)
                .map(|_| {
                    move |p: &Progress| {
                        p.wait_for(1_000_000);
                        released.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect();
            let r: Result<(), &str> =
                run_overlapped(&engine, consumers, |_p: &Progress| Err("bail"));
            assert!(r.is_err());
        }
        assert_eq!(released.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn producer_panic_still_releases_consumers_then_unwinds() {
        let engine = ExecEngine::new(2);
        let released = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let released = &released;
            let consumers: Vec<_> = (0..2)
                .map(|_| {
                    move |p: &Progress| {
                        p.wait_for(usize::MAX);
                        released.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect();
            run_overlapped(&engine, consumers, |_p: &Progress| -> () {
                panic!("producer boom")
            });
        }));
        assert!(result.is_err(), "producer panic must propagate");
        assert_eq!(
            released.load(Ordering::SeqCst),
            2,
            "floodgate must fire before the barrier on the unwind path"
        );
    }

    #[test]
    fn consumer_panic_mid_bucket_does_not_deadlock_the_round() {
        // Fault-plane satellite: one consumer dies partway through its
        // bucket while its siblings are still blocked on rows the
        // producer has yet to retire. The round must run to completion
        // — producer finishes, every surviving consumer drains, the
        // barrier releases — and only then re-raise the panic on the
        // caller. A hang here is the failure mode this pins down.
        let engine = ExecEngine::new(3);
        let survivors = AtomicUsize::new(0);
        let produced = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let survivors = &survivors;
            let consumers: Vec<_> = (0..4)
                .map(|i| {
                    move |p: &Progress| {
                        if i == 0 {
                            // Dies after its first row, mid-bucket.
                            p.wait_for(1);
                            panic!("bucket boom");
                        }
                        // Siblings wait on rows produced *after* the
                        // panic has already happened.
                        for row in 1..=8 {
                            p.wait_for(row);
                        }
                        survivors.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect();
            run_overlapped(&engine, consumers, |p: &Progress| {
                for row in 1..=8 {
                    produced.fetch_add(1, Ordering::SeqCst);
                    p.retire(row);
                }
            });
        }));
        let payload = result.expect_err("consumer panic must reach the caller");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"bucket boom"));
        assert_eq!(produced.load(Ordering::SeqCst), 8, "producer must finish");
        assert_eq!(
            survivors.load(Ordering::SeqCst),
            3,
            "surviving consumers must all drain before the re-raise"
        );
    }

    #[test]
    fn consumer_panic_is_reraised_on_caller() {
        let engine = ExecEngine::new(2);
        let consumers: Vec<_> = (0..2)
            .map(|i| {
                move |_p: &Progress| {
                    if i == 1 {
                        panic!("bucket boom");
                    }
                }
            })
            .collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_overlapped(&engine, consumers, |p: &Progress| p.open());
        }));
        let payload = result.expect_err("consumer panic must reach the caller");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"bucket boom"));
    }
}
