//! Deterministic fork-join execution engine for the simulation hot
//! paths (gossip SpMM, fused gossip+SGD, variance capture, mean-model
//! construction).
//!
//! ## Design: tile ownership, not work stealing
//!
//! The engine partitions the parameter axis `[0, P)` into at most
//! `threads` contiguous column ranges and hands each range to exactly
//! one worker for the whole call ([`ExecEngine::run_jobs`] +
//! [`partition`]). There are no queues and no work stealing: ownership
//! of every output element is decided *before* any thread starts, purely
//! from `(P, threads, min_chunk)`.
//!
//! Fork-join is one scheduling shape over this ownership map; the
//! bucket-granular overlapped pipeline ([`pipeline::run_overlapped`])
//! is the other — same pool, same determinism argument, with a
//! produced-row frontier ([`pipeline::Progress`]) in place of the two
//! global phase barriers.
//!
//! ## Why results are bit-identical for any thread count
//!
//! Every kernel routed through this engine computes each output element
//! `out[i][k]` from a reduction whose operand order depends only on `i`
//! (the graph row's neighbor order) and never on `k`'s tile, the number
//! of tiles, or which worker owns the tile. Column partitioning
//! therefore changes *which core* executes the per-element float
//! sequence, but not the sequence itself — IEEE-754 operations are
//! deterministic, so `threads = 1, 2, 4, 8 …` all produce the same bits.
//!
//! The same argument extends to **scalar reductions**
//! ([`ExecEngine::run_reduce`], [`ExecEngine::run_reduce_rows`]): the
//! input is split into *fixed-granularity* tiles whose boundaries
//! depend only on
//! `(len, granularity)` — never on the thread count — each tile yields
//! one partial computed by a serial in-order pass, and the partials are
//! combined on the calling thread in ascending tile order. Which worker
//! computed a partial is unobservable; the float sequence per partial
//! and the combine sequence are both fixed. Verified exhaustively in
//! `rust/tests/exec_determinism.rs`.
//!
//! One consequence worth knowing: there is no atomic/reduction-tree
//! summation anywhere (those *would* change operand order with thread
//! count).
//!
//! ## Threading model: a persistent parked pool
//!
//! An [`ExecEngine`] with `threads > 1` spawns `threads − 1` workers
//! **exactly once**, at construction ([`pool::WorkerPool`]). Between
//! calls the workers sit parked in a blocking channel `recv`; a
//! fork-join round costs one channel send per worker plus one condvar
//! wait on the caller — the ~tens-of-µs per-call scoped-thread spawn of
//! the PR 1 engine is gone, which matters for the O(n·P) passes that
//! run every iteration (gossip, variance capture) at small P or high
//! frequency. Job 0 always executes on the calling thread. Cloned
//! engines share the same pool (`Arc`); dropping the last clone closes
//! the channels and **joins every worker** before returning, so no
//! thread outlives the engine.
//!
//! Because pool workers are long-lived, jobs cross a `'static` channel
//! and the caller's borrows are erased (`unsafe`, localized to
//! [`ExecEngine::run_jobs`]). Soundness rests on the fork-join barrier:
//! `run_jobs` does not return — and does not unwind past the borrowed
//! buffers — until every dispatched job has counted down its latch, so
//! every borrow strictly outlives every use. This is the same
//! structured-concurrency argument `std::thread::scope` makes, with the
//! join moved from thread exit to a per-call latch. A panicking job is
//! contained in the worker, still counts down, and is re-raised on the
//! calling thread after the barrier.
//!
//! [`partition`]'s `min_chunk` keeps tiny inputs on the calling thread
//! so small-model runs never touch the pool. NUMA pinning of workers to
//! their owned column ranges is the next rung (see ROADMAP.md §Open
//! items); `GossipEngine::ensure_scratch` already first-touches scratch
//! rows inside the owning worker's tile as groundwork.
//!
//! ## The SIMD layer underneath
//!
//! The inner loops every tile job runs live in [`simd`]: explicit AVX2
//! `f32x8` kernels behind runtime feature detection, with a
//! fixed-8-lane scalar fallback sharing the same virtual lane width and
//! accumulation order. Both paths are bit-identical by construction, so
//! the determinism argument above is unaffected by *how wide* the
//! registers are — `threads` and AVX2 availability are both pure
//! wall-clock knobs.

pub mod pipeline;
pub mod pool;
mod reduce;
pub mod simd;

pub use pipeline::{run_overlapped, BucketTable, Progress, DEFAULT_BUCKET_ELEMS};
pub use pool::WorkerPool;
pub use reduce::{reduce_tiles, REDUCE_GRANULARITY};

use pool::{run_caught, Latch, PanicSlot, Task, TaskGuard};
use std::num::NonZeroUsize;
use std::ops::Range;
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Mutex};

/// Resolve a user-facing thread-count knob: `0` means "auto" (all
/// available cores), anything else is taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// Split `[0, len)` into at most `parts` contiguous ranges of at least
/// `min_chunk` elements each (except when `len < min_chunk`, which
/// yields a single short range). Ranges are returned in ascending order,
/// cover `[0, len)` exactly, and differ in length by at most one — the
/// deterministic tile-ownership map of the engine.
pub fn partition(len: usize, parts: usize, min_chunk: usize) -> Vec<Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let max_by_chunk = if min_chunk == 0 { parts } else { len.div_ceil(min_chunk) };
    let k = parts.max(1).min(max_by_chunk).max(1);
    let base = len / k;
    let extra = len % k; // first `extra` ranges get one more element
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Transpose row-major mutable buffers into per-worker column views:
/// `column_views(rows, ranges)[w][i]` is row `i` restricted to
/// `ranges[w]`. The views are disjoint by construction (ranges are
/// disjoint), which is what lets each worker own its columns of *every*
/// row without any synchronization.
pub fn column_views<'a>(
    rows: Vec<&'a mut [f32]>,
    ranges: &[Range<usize>],
) -> Vec<Vec<&'a mut [f32]>> {
    let mut per_worker: Vec<Vec<&'a mut [f32]>> =
        ranges.iter().map(|_| Vec::with_capacity(rows.len())).collect();
    for row in rows {
        let mut rest = row;
        let mut offset = 0;
        for (w, r) in ranges.iter().enumerate() {
            // `take` moves the remainder out of `rest` so the split
            // halves keep the full `'a` lifetime.
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.end - offset);
            per_worker[w].push(head);
            rest = tail;
            offset = r.end;
        }
    }
    per_worker
}

/// Blocks on the latch when dropped — the fork-join barrier holds on
/// both the normal and the unwinding exit path of `run_jobs`, which is
/// what the lifetime-erasure safety argument requires.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Erase a job's borrow lifetime so it can cross the pool's `'static`
/// channel.
///
/// # Safety
///
/// The caller must guarantee the job has finished running before any
/// borrow captured in it ends. `run_jobs` guarantees this with
/// [`WaitGuard`]: the latch wait sits below every captured borrow on
/// the caller's stack and runs on both exit paths.
unsafe fn erase_task(task: Box<dyn FnOnce() + Send + '_>) -> Task {
    std::mem::transmute(task)
}

/// The engine: a fixed worker count, the persistent pool, and the
/// fork-join runner. Cheap to clone (clones share the pool).
#[derive(Debug, Clone)]
pub struct ExecEngine {
    threads: usize,
    pool: Option<Arc<WorkerPool>>,
}

impl Default for ExecEngine {
    fn default() -> Self {
        Self::serial()
    }
}

impl ExecEngine {
    /// Engine with `threads` workers; `0` = auto (available cores). The
    /// `threads − 1` pool workers are spawned here, exactly once; every
    /// later call reuses them.
    pub fn new(threads: usize) -> Self {
        let threads = resolve_threads(threads).max(1);
        let pool = (threads > 1).then(|| Arc::new(WorkerPool::new(threads - 1)));
        ExecEngine { threads, pool }
    }

    /// Single-threaded engine (the default; identical results, see the
    /// module docs' determinism argument). Never spawns a thread.
    pub fn serial() -> Self {
        ExecEngine {
            threads: 1,
            pool: None,
        }
    }

    /// Worker count (calling thread included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Live pool-worker counter, when this engine owns a pool:
    /// `threads() − 1` while the engine is up, `0` once the last clone
    /// has been dropped (drop joins the workers). Lets tests prove the
    /// spawn-once / join-on-drop contract.
    pub fn pool_liveness(&self) -> Option<Arc<AtomicUsize>> {
        self.pool.as_ref().map(|p| p.liveness())
    }

    /// Partition `[0, len)` for this engine's worker count.
    pub fn partition(&self, len: usize, min_chunk: usize) -> Vec<Range<usize>> {
        partition(len, self.threads, min_chunk)
    }

    /// Run the jobs to completion, one per worker. Job 0 executes on the
    /// calling thread; the rest are dispatched to the persistent pool
    /// and joined (latch barrier) before return. Serial engines run all
    /// jobs in order on the calling thread; no thread is ever spawned
    /// per call.
    pub fn run_jobs<F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send,
    {
        let mut it = jobs.into_iter();
        let Some(first) = it.next() else { return };
        let rest: Vec<F> = it.collect();
        let pool = match self.pool.as_deref() {
            Some(pool) if !rest.is_empty() => pool,
            _ => {
                first();
                for job in rest {
                    job();
                }
                return;
            }
        };

        let latch = Arc::new(Latch::new(rest.len()));
        let panic_slot: PanicSlot = Arc::new(Mutex::new(None));
        let tasks: Vec<Task> = rest
            .into_iter()
            .map(|job| {
                let guard = TaskGuard {
                    latch: latch.clone(),
                };
                let slot = panic_slot.clone();
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    // The guard counts the latch down when the task
                    // ends on any path; run_caught stashes a panic
                    // payload (with the job's borrows already dropped)
                    // so the caller can resume it with the original
                    // message after the barrier.
                    let _g = guard;
                    run_caught(job, &slot);
                });
                // SAFETY: the WaitGuard below blocks until this task's
                // latch fires, on both the normal and unwind path, so
                // every borrow captured in `job` outlives its use.
                unsafe { erase_task(task) }
            })
            .collect();
        {
            // The barrier guard must exist BEFORE any task is handed
            // out: if dispatch or job 0 unwinds, the drop still waits
            // for every in-flight task, upholding the erase_task
            // invariant (dispatch itself never strands the latch — a
            // task it cannot deliver runs inline, see WorkerPool).
            let _barrier = WaitGuard(&latch);
            pool.dispatch(tasks);
            first();
        }
        if let Some(payload) = panic_slot.lock().expect("panic slot lock").take() {
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn partition_covers_exactly_and_is_balanced() {
        for (len, parts, min_chunk) in
            [(10, 3, 1), (1_000_000, 4, 4096), (5, 8, 1), (4096, 8, 4096), (1, 4, 4096)]
        {
            let ranges = partition(len, parts, min_chunk);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= parts);
            assert_eq!(ranges.first().unwrap().start, 0);
            assert_eq!(ranges.last().unwrap().end, len);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must tile");
            }
            let sizes: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "balanced within one element: {sizes:?}");
        }
    }

    #[test]
    fn partition_respects_min_chunk() {
        // 10k columns at min_chunk 4096 → at most 3 ranges even with 8 workers.
        let ranges = partition(10_000, 8, 4096);
        assert!(ranges.len() <= 3, "{ranges:?}");
        // Tiny input stays on one worker.
        assert_eq!(partition(100, 8, 4096).len(), 1);
        assert!(partition(0, 4, 1).is_empty());
    }

    #[test]
    fn partition_is_deterministic() {
        assert_eq!(partition(999, 4, 16), partition(999, 4, 16));
    }

    #[test]
    fn column_views_are_disjoint_and_cover() {
        let mut rows = vec![vec![0.0f32; 10]; 3];
        let ranges = partition(10, 3, 1);
        {
            let views = column_views(rows.iter_mut().map(Vec::as_mut_slice).collect(), &ranges);
            assert_eq!(views.len(), ranges.len());
            for (w, view) in views.into_iter().enumerate() {
                assert_eq!(view.len(), 3, "one slice per row");
                for (i, chunk) in view.into_iter().enumerate() {
                    assert_eq!(chunk.len(), ranges[w].end - ranges[w].start);
                    for v in chunk.iter_mut() {
                        *v += (w * 3 + i + 1) as f32; // mark ownership
                    }
                }
            }
        }
        // Every element written exactly once.
        for (i, row) in rows.iter().enumerate() {
            for (k, &v) in row.iter().enumerate() {
                let w = ranges.iter().position(|r| r.contains(&k)).unwrap();
                assert_eq!(v, (w * 3 + i + 1) as f32, "row {i} col {k}");
            }
        }
    }

    #[test]
    fn run_jobs_executes_all_jobs_in_parallel_sum() {
        let engine = ExecEngine::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let ranges = engine.partition(data.len(), 1);
        let mut partials = vec![0u64; ranges.len()];
        {
            let data = &data;
            let jobs: Vec<_> = partials
                .iter_mut()
                .zip(ranges.iter().cloned())
                .map(|(out, r)| move || *out = data[r].iter().sum::<u64>())
                .collect();
            engine.run_jobs(jobs);
        }
        assert_eq!(partials.iter().sum::<u64>(), 499_500);
    }

    #[test]
    fn serial_engine_spawns_nothing_and_still_runs() {
        let engine = ExecEngine::serial();
        assert_eq!(engine.threads(), 1);
        assert!(engine.pool_liveness().is_none(), "serial engine has no pool");
        let mut hit = false;
        engine.run_jobs(vec![|| hit = true]);
        assert!(hit);
    }

    #[test]
    fn serial_engine_runs_excess_jobs_in_order() {
        let engine = ExecEngine::serial();
        let order = std::sync::Mutex::new(Vec::new());
        let jobs: Vec<_> = (0..4)
            .map(|i| {
                let order = &order;
                move || order.lock().unwrap().push(i)
            })
            .collect();
        engine.run_jobs(jobs);
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn engine_clone_shares_one_pool() {
        let engine = ExecEngine::new(4);
        let live = engine.pool_liveness().expect("pooled");
        let clone = engine.clone();
        assert_eq!(live.load(Ordering::SeqCst), 3, "clone spawns nothing");
        drop(engine);
        assert_eq!(live.load(Ordering::SeqCst), 3, "pool outlives first clone");
        drop(clone);
        assert_eq!(live.load(Ordering::SeqCst), 0, "last drop joins workers");
    }

    #[test]
    fn pooled_job_panic_is_reraised_on_caller() {
        let engine = ExecEngine::new(2);
        let mk = |bomb: bool| {
            move || {
                if bomb {
                    panic!("job boom");
                }
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run_jobs(vec![mk(false), mk(true)]);
        }));
        let payload = result.expect_err("worker panic must reach the caller");
        assert_eq!(
            payload.downcast_ref::<&str>(),
            Some(&"job boom"),
            "original panic payload must be resumed on the caller"
        );
        // The engine stays usable after a contained panic.
        let mut flags = vec![false; 2];
        {
            let jobs: Vec<_> = flags.iter_mut().map(|f| move || *f = true).collect();
            engine.run_jobs(jobs);
        }
        assert!(flags.iter().all(|&f| f));
    }

    #[test]
    fn resolve_threads_auto_is_positive() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
