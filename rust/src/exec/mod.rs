//! Deterministic fork-join execution engine for the simulation hot
//! paths (gossip SpMM, fused gossip+SGD).
//!
//! ## Design: tile ownership, not work stealing
//!
//! The engine partitions the parameter axis `[0, P)` into at most
//! `threads` contiguous column ranges and hands each range to exactly
//! one worker for the whole call ([`ExecEngine::run_jobs`] +
//! [`partition`]). There are no queues and no work stealing: ownership
//! of every output element is decided *before* any thread starts, purely
//! from `(P, threads, min_chunk)`.
//!
//! ## Why results are bit-identical for any thread count
//!
//! Every kernel routed through this engine computes each output element
//! `out[i][k]` from a reduction whose operand order depends only on `i`
//! (the graph row's neighbor order) and never on `k`'s tile, the number
//! of tiles, or which worker owns the tile. Column partitioning
//! therefore changes *which core* executes the per-element float
//! sequence, but not the sequence itself — IEEE-754 operations are
//! deterministic, so `threads = 1, 2, 4, 8 …` all produce the same bits.
//! This is verified exhaustively in `rust/tests/exec_determinism.rs`.
//!
//! Two consequences worth knowing:
//!  * no atomic/reduction-tree summation anywhere (those *would* change
//!    operand order with thread count);
//!  * a worker never writes outside its column range, so the disjoint
//!    `&mut` views handed out by [`column_views`] are safe Rust, no
//!    `unsafe` required.
//!
//! ## Threading model
//!
//! Workers are scoped threads (`std::thread::scope`): spawned per call,
//! joined before the call returns, so they can borrow the caller's
//! buffers directly. Spawn cost (~tens of µs) is negligible against the
//! O(n·P) passes this engine exists for; [`partition`]'s `min_chunk`
//! keeps tiny inputs on the calling thread so small-model runs pay
//! nothing. A persistent NUMA-pinned pool is a roadmap follow-on (see
//! ROADMAP.md §Open items).

use std::num::NonZeroUsize;
use std::ops::Range;

/// Resolve a user-facing thread-count knob: `0` means "auto" (all
/// available cores), anything else is taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// Split `[0, len)` into at most `parts` contiguous ranges of at least
/// `min_chunk` elements each (except when `len < min_chunk`, which
/// yields a single short range). Ranges are returned in ascending order,
/// cover `[0, len)` exactly, and differ in length by at most one — the
/// deterministic tile-ownership map of the engine.
pub fn partition(len: usize, parts: usize, min_chunk: usize) -> Vec<Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let max_by_chunk = if min_chunk == 0 { parts } else { len.div_ceil(min_chunk) };
    let k = parts.max(1).min(max_by_chunk).max(1);
    let base = len / k;
    let extra = len % k; // first `extra` ranges get one more element
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Transpose row-major mutable buffers into per-worker column views:
/// `column_views(rows, ranges)[w][i]` is row `i` restricted to
/// `ranges[w]`. The views are disjoint by construction (ranges are
/// disjoint), which is what lets each worker own its columns of *every*
/// row without any synchronization.
pub fn column_views<'a>(
    rows: Vec<&'a mut [f32]>,
    ranges: &[Range<usize>],
) -> Vec<Vec<&'a mut [f32]>> {
    let mut per_worker: Vec<Vec<&'a mut [f32]>> =
        ranges.iter().map(|_| Vec::with_capacity(rows.len())).collect();
    for row in rows {
        let mut rest = row;
        let mut offset = 0;
        for (w, r) in ranges.iter().enumerate() {
            // `take` moves the remainder out of `rest` so the split
            // halves keep the full `'a` lifetime.
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.end - offset);
            per_worker[w].push(head);
            rest = tail;
            offset = r.end;
        }
    }
    per_worker
}

/// The engine: a fixed worker count and the fork-join runner.
#[derive(Debug, Clone)]
pub struct ExecEngine {
    threads: usize,
}

impl Default for ExecEngine {
    fn default() -> Self {
        Self::serial()
    }
}

impl ExecEngine {
    /// Engine with `threads` workers; `0` = auto (available cores).
    pub fn new(threads: usize) -> Self {
        ExecEngine {
            threads: resolve_threads(threads).max(1),
        }
    }

    /// Single-threaded engine (the default; identical results, see the
    /// module docs' determinism argument).
    pub fn serial() -> Self {
        ExecEngine { threads: 1 }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Partition `[0, len)` for this engine's worker count.
    pub fn partition(&self, len: usize, min_chunk: usize) -> Vec<Range<usize>> {
        partition(len, self.threads, min_chunk)
    }

    /// Run the jobs to completion, one per worker. Job 0 executes on the
    /// calling thread; the rest on scoped threads joined before return.
    /// With zero or one job no thread is ever spawned.
    pub fn run_jobs<F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send,
    {
        let mut it = jobs.into_iter();
        let Some(first) = it.next() else { return };
        let rest: Vec<F> = it.collect();
        if rest.is_empty() {
            first();
            return;
        }
        std::thread::scope(|scope| {
            for job in rest {
                scope.spawn(job);
            }
            first();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly_and_is_balanced() {
        for (len, parts, min_chunk) in
            [(10, 3, 1), (1_000_000, 4, 4096), (5, 8, 1), (4096, 8, 4096), (1, 4, 4096)]
        {
            let ranges = partition(len, parts, min_chunk);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= parts);
            assert_eq!(ranges.first().unwrap().start, 0);
            assert_eq!(ranges.last().unwrap().end, len);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must tile");
            }
            let sizes: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "balanced within one element: {sizes:?}");
        }
    }

    #[test]
    fn partition_respects_min_chunk() {
        // 10k columns at min_chunk 4096 → at most 3 ranges even with 8 workers.
        let ranges = partition(10_000, 8, 4096);
        assert!(ranges.len() <= 3, "{ranges:?}");
        // Tiny input stays on one worker.
        assert_eq!(partition(100, 8, 4096).len(), 1);
        assert!(partition(0, 4, 1).is_empty());
    }

    #[test]
    fn partition_is_deterministic() {
        assert_eq!(partition(999, 4, 16), partition(999, 4, 16));
    }

    #[test]
    fn column_views_are_disjoint_and_cover() {
        let mut rows = vec![vec![0.0f32; 10]; 3];
        let ranges = partition(10, 3, 1);
        {
            let views = column_views(rows.iter_mut().map(Vec::as_mut_slice).collect(), &ranges);
            assert_eq!(views.len(), ranges.len());
            for (w, view) in views.into_iter().enumerate() {
                assert_eq!(view.len(), 3, "one slice per row");
                for (i, chunk) in view.into_iter().enumerate() {
                    assert_eq!(chunk.len(), ranges[w].end - ranges[w].start);
                    for v in chunk.iter_mut() {
                        *v += (w * 3 + i + 1) as f32; // mark ownership
                    }
                }
            }
        }
        // Every element written exactly once.
        for (i, row) in rows.iter().enumerate() {
            for (k, &v) in row.iter().enumerate() {
                let w = ranges.iter().position(|r| r.contains(&k)).unwrap();
                assert_eq!(v, (w * 3 + i + 1) as f32, "row {i} col {k}");
            }
        }
    }

    #[test]
    fn run_jobs_executes_all_jobs_in_parallel_sum() {
        let engine = ExecEngine::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let ranges = engine.partition(data.len(), 1);
        let mut partials = vec![0u64; ranges.len()];
        {
            let data = &data;
            let jobs: Vec<_> = partials
                .iter_mut()
                .zip(ranges.iter().cloned())
                .map(|(out, r)| move || *out = data[r].iter().sum::<u64>())
                .collect();
            engine.run_jobs(jobs);
        }
        assert_eq!(partials.iter().sum::<u64>(), 499_500);
    }

    #[test]
    fn serial_engine_spawns_nothing_and_still_runs() {
        let engine = ExecEngine::serial();
        assert_eq!(engine.threads(), 1);
        let mut hit = false;
        engine.run_jobs(vec![|| hit = true]);
        assert!(hit);
    }

    #[test]
    fn resolve_threads_auto_is_positive() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
