//! The explicit SIMD kernel layer — every f32 inner loop of the
//! training hot path, written twice with **identical semantics**:
//!
//! * an **AVX2** path (`f32x8` intrinsics, selected by runtime feature
//!   detection), and
//! * a **fixed-8-lane scalar fallback** ([`scalar`]) that uses the same
//!   virtual lane width and the same per-lane accumulation order.
//!
//! ## The bit-identity contract
//!
//! The two paths produce **bit-identical results**, by construction:
//!
//! * Elementwise kernels ([`axpy`], [`scale`], [`scale_in_place`],
//!   [`sgd_step`]) compute each output element from its own inputs with
//!   the same IEEE-754 operation sequence — vectorization only changes
//!   *which register* holds an element, never its float sequence. The
//!   AVX2 path deliberately uses separate multiply + add (never FMA,
//!   whose fused rounding would diverge from the scalar sequence).
//! * Reduction kernels ([`sumsq_f64`], [`sumsq_f32`]) accumulate into
//!   **8 virtual lanes** — element `i` always lands in lane `i % 8`,
//!   in index order within its lane — and both paths combine the final
//!   lanes in ascending lane order on exit. The scalar fallback keeps
//!   an 8-wide accumulator array and walks the input in the exact same
//!   pattern, so the float sequence per lane is shared.
//!
//! This is what lets the execution engine's determinism guarantee
//! (bit-exact across 1/2/4/8 threads, `rust/src/exec/mod.rs`) survive
//! vectorization unchanged: thread count decides *where* a tile runs,
//! feature detection decides *how wide* the registers are, and neither
//! decision can move a bit of output. Proof-by-test in
//! `rust/tests/exec_determinism.rs`.
//!
//! ## Dispatch
//!
//! [`simd_active`] reports whether the AVX2 path is in use. It is off
//! when the CPU lacks AVX2, when the `ADA_SIMD` environment variable is
//! set to `scalar`/`off`/`0` (the CI fallback job), or after
//! [`force_scalar`]`(true)` (the process-global test/bench knob the
//! `simd_vs_scalar` bench section uses to time both paths in one run).
//! On non-x86_64 targets only the scalar path exists.
//!
//! Loads and stores are unaligned (`loadu`/`storeu`): rows of a
//! [`crate::util::matrix::ReplicaMatrix`] start 64-byte aligned, but
//! the engine's column tiles begin at arbitrary offsets within a row,
//! and unaligned AVX2 accesses are free when the address happens to be
//! aligned.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// The virtual lane width both paths share.
pub const LANES: usize = 8;

/// Process-global scalar override (test/bench knob).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// `ADA_SIMD` environment override, read once.
fn env_scalar() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| {
        matches!(
            std::env::var("ADA_SIMD").as_deref(),
            Ok("scalar") | Ok("off") | Ok("0") | Ok("false")
        )
    })
}

/// Force the scalar fallback for the rest of the process (`true`) or
/// return to auto-detection (`false`). Used by the `simd_vs_scalar`
/// bench section and the bit-identity tests; results are identical
/// either way — this is purely a wall-clock knob.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Whether the AVX2 path is currently selected.
pub fn simd_active() -> bool {
    if FORCE_SCALAR.load(Ordering::Relaxed) || env_scalar() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// `out[i] += w * src[i]` — the SpMM accumulation inner loop.
#[inline]
pub fn axpy(out: &mut [f32], src: &[f32], w: f32) {
    // Hard assert: a silent partial update from a mismatched tile would
    // be far worse than the one branch this costs per kernel call.
    assert_eq!(out.len(), src.len(), "axpy slices must have equal length");
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::axpy(out, src, w) };
        return;
    }
    scalar::axpy(out, src, w);
}

/// `out[i] = w * src[i]` — the SpMM seeding pass (first neighbor).
#[inline]
pub fn scale(out: &mut [f32], src: &[f32], w: f32) {
    assert_eq!(out.len(), src.len(), "scale slices must have equal length");
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::scale(out, src, w) };
        return;
    }
    scalar::scale(out, src, w);
}

/// `out[i] *= w` — the mean pass's final rescale.
#[inline]
pub fn scale_in_place(out: &mut [f32], w: f32) {
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::scale_in_place(out, w) };
        return;
    }
    scalar::scale_in_place(out, w);
}

/// The momentum-SGD update, elementwise over one row (or one tile of a
/// row): `eff = g + wd·θ; v = mu·v + eff; θ -= lr·v` — exactly
/// [`crate::optim::SgdState::step`]'s float sequence, which routes
/// through this kernel.
#[inline]
pub fn sgd_step(params: &mut [f32], vel: &mut [f32], grads: &[f32], mu: f32, wd: f32, lr: f32) {
    assert_eq!(params.len(), grads.len(), "sgd_step params/grads length mismatch");
    assert_eq!(params.len(), vel.len(), "sgd_step params/velocity length mismatch");
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::sgd_step(params, vel, grads, mu, wd, lr) };
        return;
    }
    scalar::sgd_step(params, vel, grads, mu, wd, lr);
}

/// `Σ x_i²` accumulated in f64 over 8 virtual lanes — the L2-norm
/// primitive of the variance capture. Element `i` lands in lane
/// `i % 8`; lanes are combined in ascending order.
#[inline]
pub fn sumsq_f64(x: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        return unsafe { avx2::sumsq_f64(x) };
    }
    scalar::sumsq_f64(x)
}

/// `Σ x_i²` in f32 over the same 8-lane pattern — LARS's per-layer
/// norm primitive.
#[inline]
pub fn sumsq_f32(x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        return unsafe { avx2::sumsq_f32(x) };
    }
    scalar::sumsq_f32(x)
}

/// The fixed-8-lane scalar reference path. Public so tests and the
/// `simd_vs_scalar` bench can call it directly regardless of dispatch
/// state; the dispatched functions above must match it bit-for-bit.
pub mod scalar {
    use super::LANES;

    /// Scalar [`super::axpy`].
    pub fn axpy(out: &mut [f32], src: &[f32], w: f32) {
        for (o, &s) in out.iter_mut().zip(src) {
            *o += w * s;
        }
    }

    /// Scalar [`super::scale`].
    pub fn scale(out: &mut [f32], src: &[f32], w: f32) {
        for (o, &s) in out.iter_mut().zip(src) {
            *o = w * s;
        }
    }

    /// Scalar [`super::scale_in_place`].
    pub fn scale_in_place(out: &mut [f32], w: f32) {
        for v in out.iter_mut() {
            *v *= w;
        }
    }

    /// Scalar [`super::sgd_step`].
    pub fn sgd_step(
        params: &mut [f32],
        vel: &mut [f32],
        grads: &[f32],
        mu: f32,
        wd: f32,
        lr: f32,
    ) {
        for ((p, v), &g) in params.iter_mut().zip(vel.iter_mut()).zip(grads) {
            let eff = g + wd * *p;
            *v = mu * *v + eff;
            *p -= lr * *v;
        }
    }

    /// Scalar [`super::sumsq_f64`]: 8 virtual f64 lanes, element `i` in
    /// lane `i % 8`, lanes combined ascending.
    pub fn sumsq_f64(x: &[f32]) -> f64 {
        let mut lanes = [0.0f64; LANES];
        let mut chunks = x.chunks_exact(LANES);
        for c in chunks.by_ref() {
            for (lane, &v) in lanes.iter_mut().zip(c) {
                let v = v as f64;
                *lane += v * v;
            }
        }
        for (lane, &v) in lanes.iter_mut().zip(chunks.remainder()) {
            let v = v as f64;
            *lane += v * v;
        }
        lanes.iter().sum()
    }

    /// Scalar [`super::sumsq_f32`]: same lane pattern in f32.
    pub fn sumsq_f32(x: &[f32]) -> f32 {
        let mut lanes = [0.0f32; LANES];
        let mut chunks = x.chunks_exact(LANES);
        for c in chunks.by_ref() {
            for (lane, &v) in lanes.iter_mut().zip(c) {
                *lane += v * v;
            }
        }
        for (lane, &v) in lanes.iter_mut().zip(chunks.remainder()) {
            *lane += v * v;
        }
        lanes.iter().sum()
    }
}

/// The AVX2 path. Every function mirrors its [`scalar`] twin's float
/// sequence exactly — multiply + add, never FMA; reductions keep the
/// 8-virtual-lane accumulators and combine them in ascending lane
/// order through the same scalar epilogue.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::LANES;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(out: &mut [f32], src: &[f32], w: f32) {
        let len = out.len().min(src.len());
        let wv = _mm256_set1_ps(w);
        let mut i = 0;
        while i + LANES <= len {
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let r = _mm256_add_ps(o, _mm256_mul_ps(wv, s));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        while i < len {
            out[i] += w * src[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(out: &mut [f32], src: &[f32], w: f32) {
        let len = out.len().min(src.len());
        let wv = _mm256_set1_ps(w);
        let mut i = 0;
        while i + LANES <= len {
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(wv, s));
            i += LANES;
        }
        while i < len {
            out[i] = w * src[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_in_place(out: &mut [f32], w: f32) {
        let len = out.len();
        let wv = _mm256_set1_ps(w);
        let mut i = 0;
        while i + LANES <= len {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(wv, o));
            i += LANES;
        }
        while i < len {
            out[i] *= w;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_step(
        params: &mut [f32],
        vel: &mut [f32],
        grads: &[f32],
        mu: f32,
        wd: f32,
        lr: f32,
    ) {
        let len = params.len().min(vel.len()).min(grads.len());
        let muv = _mm256_set1_ps(mu);
        let wdv = _mm256_set1_ps(wd);
        let lrv = _mm256_set1_ps(lr);
        let mut i = 0;
        while i + LANES <= len {
            let p = _mm256_loadu_ps(params.as_ptr().add(i));
            let v = _mm256_loadu_ps(vel.as_ptr().add(i));
            let g = _mm256_loadu_ps(grads.as_ptr().add(i));
            // eff = g + wd*p; v' = mu*v + eff; p' = p - lr*v' — separate
            // mul/add/sub so each lane's rounding matches the scalar path.
            let eff = _mm256_add_ps(g, _mm256_mul_ps(wdv, p));
            let v2 = _mm256_add_ps(_mm256_mul_ps(muv, v), eff);
            let p2 = _mm256_sub_ps(p, _mm256_mul_ps(lrv, v2));
            _mm256_storeu_ps(vel.as_mut_ptr().add(i), v2);
            _mm256_storeu_ps(params.as_mut_ptr().add(i), p2);
            i += LANES;
        }
        while i < len {
            let eff = grads[i] + wd * params[i];
            vel[i] = mu * vel[i] + eff;
            params[i] -= lr * vel[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sumsq_f64(x: &[f32]) -> f64 {
        // Lanes 0..4 in acc_lo, lanes 4..8 in acc_hi; element i lands in
        // lane i % 8 — the exact pattern of the scalar fallback.
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut chunks = x.chunks_exact(LANES);
        for c in chunks.by_ref() {
            let v = _mm256_loadu_ps(c.as_ptr());
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(lo, lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(hi, hi));
        }
        let mut lanes = [0.0f64; LANES];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
        for (lane, &v) in lanes.iter_mut().zip(chunks.remainder()) {
            let v = v as f64;
            *lane += v * v;
        }
        lanes.iter().sum()
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sumsq_f32(x: &[f32]) -> f32 {
        let mut acc = _mm256_setzero_ps();
        let mut chunks = x.chunks_exact(LANES);
        for c in chunks.by_ref() {
            let v = _mm256_loadu_ps(c.as_ptr());
            acc = _mm256_add_ps(acc, _mm256_mul_ps(v, v));
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (lane, &v) in lanes.iter_mut().zip(chunks.remainder()) {
            *lane += v * v;
        }
        lanes.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vector(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect()
    }

    /// Lengths that exercise full chunks, the remainder, and both empty
    /// and sub-lane inputs.
    const LENS: [usize; 6] = [0, 1, 7, 8, 33, 4096 + 5];

    #[test]
    fn dispatched_elementwise_kernels_match_scalar_bitwise() {
        // On AVX2 hosts this compares vector vs scalar bits; elsewhere
        // both sides are scalar and the test degenerates (still valid).
        for len in LENS {
            let src = vector(len, 1);
            let mut a = vector(len, 2);
            let mut b = a.clone();
            axpy(&mut a, &src, 0.37);
            scalar::axpy(&mut b, &src, 0.37);
            assert_eq!(a, b, "axpy len {len}");

            let mut a = vec![0.0f32; len];
            let mut b = vec![0.0f32; len];
            scale(&mut a, &src, -1.25);
            scalar::scale(&mut b, &src, -1.25);
            assert_eq!(a, b, "scale len {len}");

            let mut a = vector(len, 3);
            let mut b = a.clone();
            scale_in_place(&mut a, 0.125);
            scalar::scale_in_place(&mut b, 0.125);
            assert_eq!(a, b, "scale_in_place len {len}");
        }
    }

    #[test]
    fn dispatched_sgd_step_matches_scalar_bitwise() {
        for len in LENS {
            let g = vector(len, 4);
            let (mut pa, mut va) = (vector(len, 5), vector(len, 6));
            let (mut pb, mut vb) = (pa.clone(), va.clone());
            for _ in 0..3 {
                sgd_step(&mut pa, &mut va, &g, 0.9, 1e-4, 0.05);
                scalar::sgd_step(&mut pb, &mut vb, &g, 0.9, 1e-4, 0.05);
            }
            assert_eq!(pa, pb, "params len {len}");
            assert_eq!(va, vb, "velocity len {len}");
        }
    }

    #[test]
    fn dispatched_reductions_match_scalar_bitwise() {
        for len in LENS {
            let x = vector(len, 7);
            assert_eq!(
                sumsq_f64(&x).to_bits(),
                scalar::sumsq_f64(&x).to_bits(),
                "sumsq_f64 len {len}"
            );
            assert_eq!(
                sumsq_f32(&x).to_bits(),
                scalar::sumsq_f32(&x).to_bits(),
                "sumsq_f32 len {len}"
            );
        }
    }

    #[test]
    fn sumsq_agrees_with_plain_sum_numerically() {
        let x = vector(10_001, 8);
        let plain: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let lanes = sumsq_f64(&x);
        assert!(
            (plain - lanes).abs() <= 1e-9 * plain.max(1.0),
            "8-lane regrouping must stay within f64 rounding: {plain} vs {lanes}"
        );
        assert_eq!(sumsq_f64(&[]), 0.0);
        assert_eq!(sumsq_f32(&[]), 0.0);
    }

    #[test]
    fn force_scalar_disables_and_restores_dispatch() {
        let before = simd_active();
        force_scalar(true);
        assert!(!simd_active(), "forced scalar must disable the SIMD path");
        // Kernels still produce the same bits while forced.
        let src = vector(100, 9);
        let mut forced = vector(100, 10);
        let mut auto = forced.clone();
        axpy(&mut forced, &src, 0.5);
        force_scalar(false);
        assert_eq!(simd_active(), before, "auto detection must be restored");
        axpy(&mut auto, &src, 0.5);
        assert_eq!(forced, auto, "both paths must agree bitwise");
    }
}
