//! Deterministic parallel reductions over the persistent pool.
//!
//! The column-tiled kernels in [`crate::gossip`] are deterministic
//! because each *output element* has a fixed operand order. A scalar
//! reduction (an L2 norm, a variance) has no per-element outputs — its
//! operand order **is** the grouping of the sum, so naively splitting
//! it by worker count would change the float result with `--threads`.
//!
//! The fix is the same tile-ownership idea, extended to reductions:
//!
//!  1. [`reduce_tiles`] splits `[0, len)` into tiles of exactly
//!     `granularity` elements (last tile short). The boundaries depend
//!     only on `(len, granularity)` — never on the thread count.
//!  2. Every tile yields **one partial**, computed by a serial in-order
//!     pass over that tile. Which worker computes it is unobservable.
//!  3. The calling thread combines the partials in ascending tile
//!     order.
//!
//! The float sequence per partial and the combine sequence are both
//! functions of `(len, granularity)` alone, so results are bit-identical
//! for any worker count — including the serial engine, which walks the
//! same tiles on the calling thread. Proof-by-test in
//! `rust/tests/exec_determinism.rs`.

use super::{partition, ExecEngine};
use std::ops::Range;

/// Default reduction tile width. Matches the gossip SpMM tile so one
/// reduction tile is one cache-resident block; fixed so that every
/// reduction in the crate shares one deterministic tiling.
pub const REDUCE_GRANULARITY: usize = 4096;

/// The fixed reduction tiling of `[0, len)`: tiles of exactly
/// `granularity` elements, last tile short, ascending order. Depends
/// only on `(len, granularity)` — this is the determinism contract.
pub fn reduce_tiles(len: usize, granularity: usize) -> Vec<Range<usize>> {
    let g = granularity.max(1);
    let mut out = Vec::with_capacity(len.div_ceil(g));
    let mut start = 0;
    while start < len {
        let end = (start + g).min(len);
        out.push(start..end);
        start = end;
    }
    out
}

impl ExecEngine {
    /// Deterministic parallel reduction of `[0, len)`: `map` turns one
    /// fixed tile into a partial, `fold` combines partials in ascending
    /// tile order on the calling thread, starting from `init`. Results
    /// are bit-identical for any engine thread count (see module docs).
    pub fn run_reduce<T, M, F>(
        &self,
        len: usize,
        granularity: usize,
        map: M,
        fold: F,
        init: T,
    ) -> T
    where
        T: Clone + Send,
        M: Fn(Range<usize>) -> T + Sync,
        F: FnMut(T, T) -> T,
    {
        self.run_reduce_rows(1, len, granularity, |_, tile| map(tile), fold, init)
            .pop()
            .expect("one row")
    }

    /// [`ExecEngine::run_reduce`] over `rows` independent rows sharing
    /// one fan-out (one fork-join round for the whole `rows × tiles`
    /// grid — this is what the trainer's per-replica variance capture
    /// uses). `map(row, tile)` produces the partial of one grid cell;
    /// each row's partials are folded in ascending tile order and the
    /// per-row results are returned in row order.
    pub fn run_reduce_rows<T, M, F>(
        &self,
        rows: usize,
        len: usize,
        granularity: usize,
        map: M,
        mut fold: F,
        init: T,
    ) -> Vec<T>
    where
        T: Clone + Send,
        M: Fn(usize, Range<usize>) -> T + Sync,
        F: FnMut(T, T) -> T,
    {
        let tiles = reduce_tiles(len, granularity);
        let per_row = tiles.len();
        if rows == 0 {
            return Vec::new();
        }
        if per_row == 0 {
            return vec![init; rows];
        }
        let cells = rows * per_row;
        let mut partials: Vec<Option<T>> = Vec::with_capacity(cells);
        partials.resize_with(cells, || None);
        {
            // Workers own contiguous runs of the row-major cell grid;
            // the partial a cell holds depends only on `map` and its
            // fixed tile, never on this assignment. Mirror the gossip
            // kernels' fan-out floor: a worker must have at least one
            // full granularity tile of elements, so tiny captures (a
            // small tracked tensor slice, a small model) stay on the
            // calling thread and never pay a dispatch round-trip —
            // same tiles either way, so the bits don't move.
            let max_workers = (rows * len / granularity.max(1)).max(1);
            let parts = self.threads().min(max_workers);
            let map = &map;
            let tiles = &tiles;
            let worker_ranges = partition(cells, parts, 1);
            let mut jobs = Vec::with_capacity(worker_ranges.len());
            let mut rest: &mut [Option<T>] = &mut partials;
            let mut offset = 0usize;
            for r in &worker_ranges {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.end - offset);
                rest = tail;
                let start = offset;
                offset = r.end;
                jobs.push(move || {
                    for (k, slot) in head.iter_mut().enumerate() {
                        let cell = start + k;
                        *slot = Some(map(cell / per_row, tiles[cell % per_row].clone()));
                    }
                });
            }
            self.run_jobs(jobs);
        }
        let mut out = Vec::with_capacity(rows);
        let mut it = partials.into_iter();
        for _ in 0..rows {
            let mut acc = init.clone();
            for _ in 0..per_row {
                acc = fold(acc, it.next().expect("cell").expect("partial computed"));
            }
            out.push(acc);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiles_depend_only_on_len_and_granularity() {
        let a = reduce_tiles(10_000, 4096);
        assert_eq!(a, vec![0..4096, 4096..8192, 8192..10_000]);
        assert_eq!(a, reduce_tiles(10_000, 4096));
        assert!(reduce_tiles(0, 4096).is_empty());
        assert_eq!(reduce_tiles(5, 4096), vec![0..5]);
        // Zero granularity is clamped, not a panic.
        assert_eq!(reduce_tiles(3, 0).len(), 3);
    }

    #[test]
    fn reduce_sum_matches_serial_loop() {
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let engine = ExecEngine::new(4);
        let sum = engine.run_reduce(
            data.len(),
            128,
            |tile| data[tile].iter().sum::<f64>(),
            |a, b| a + b,
            0.0,
        );
        // Same grouping as a serial pass over the same tiles.
        let serial: f64 = reduce_tiles(data.len(), 128)
            .into_iter()
            .map(|t| data[t].iter().sum::<f64>())
            .sum();
        assert_eq!(sum, serial);
    }

    #[test]
    fn reduce_is_bit_identical_across_thread_counts() {
        let data: Vec<f64> = (0..50_000).map(|i| ((i * 37 + 11) as f64).cos()).collect();
        let reference = ExecEngine::serial().run_reduce(
            data.len(),
            4096,
            |tile| data[tile].iter().sum::<f64>(),
            |a, b| a + b,
            0.0,
        );
        for threads in [2, 3, 4, 8] {
            let engine = ExecEngine::new(threads);
            let got = engine.run_reduce(
                data.len(),
                4096,
                |tile| data[tile].iter().sum::<f64>(),
                |a, b| a + b,
                0.0,
            );
            assert_eq!(reference.to_bits(), got.to_bits(), "{threads} threads");
        }
    }

    #[test]
    fn reduce_rows_folds_each_row_independently() {
        let rows: Vec<Vec<f64>> = (0..5)
            .map(|r| (0..1000).map(|i| (r * 1000 + i) as f64).collect())
            .collect();
        let engine = ExecEngine::new(3);
        let sums = engine.run_reduce_rows(
            rows.len(),
            1000,
            64,
            |row, tile| rows[row][tile].iter().sum::<f64>(),
            |a, b| a + b,
            0.0,
        );
        for (r, s) in sums.iter().enumerate() {
            let expect: f64 = rows[r].iter().sum();
            assert!((s - expect).abs() < 1e-6, "row {r}: {s} vs {expect}");
        }
    }

    #[test]
    fn reduce_handles_empty_inputs() {
        let engine = ExecEngine::new(4);
        let z = engine.run_reduce(0, 16, |_| -> f64 { unreachable!("no tiles") }, |a, b| a + b, 7.0);
        assert_eq!(z, 7.0);
        let rows: Vec<f64> = engine.run_reduce_rows(3, 0, 16, |_, _| 0.0, |a, b| a + b, 1.5);
        assert_eq!(rows, vec![1.5; 3]);
        assert!(engine
            .run_reduce_rows(0, 10, 2, |_, _| 0.0f64, |a, b| a + b, 0.0)
            .is_empty());
    }
}
