//! The persistent worker pool behind [`crate::exec::ExecEngine`].
//!
//! Workers are spawned **once** (at engine construction), sit parked in
//! a blocking channel `recv` between calls, and are joined on drop. The
//! per-call cost of a fork-join round is therefore one channel send per
//! worker plus one condvar wait on the caller — the ~tens-of-µs scoped
//! thread spawn that PR 1 paid on every hot-loop call is gone.
//!
//! ## Fork-join protocol
//!
//! [`WorkerPool::dispatch`] hands each task to a fixed worker
//! (round-robin over the worker index — tasks produced by
//! [`crate::exec::partition`] never exceed the worker count, so in
//! practice the mapping is one task per worker). Completion is signalled
//! through a count-down [`Latch`] embedded in the task wrapper by the
//! caller ([`crate::exec::ExecEngine::run_jobs`]), which blocks until
//! every dispatched task has finished. That barrier is what makes the
//! lifetime erasure in `run_jobs` sound: borrowed buffers outlive every
//! task because the call does not return (and does not unwind past the
//! borrow) until all tasks are done.
//!
//! ## Panic containment
//!
//! A panicking task is caught inside its wrapper (`catch_unwind`) so
//! the worker survives for subsequent calls; the wrapper stashes the
//! original payload ([`PanicSlot`]) and still counts the latch down,
//! and `run_jobs` `resume_unwind`s it on the calling thread after the
//! barrier — the same observable behaviour (original message included)
//! as the old scoped-thread engine, without poisoning the pool.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A lifetime-erased unit of work (see the safety argument in
/// [`crate::exec::ExecEngine::run_jobs`]).
pub(crate) type Task = Box<dyn FnOnce() + Send + 'static>;

/// Count-down completion barrier for one fork-join round.
pub(crate) struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    pub(crate) fn new(count: usize) -> Self {
        Latch {
            remaining: Mutex::new(count),
            done: Condvar::new(),
        }
    }

    /// Signal one task finished (called from the task wrapper's drop so
    /// it fires even while a task panic unwinds).
    pub(crate) fn count_down(&self) {
        let mut r = self.remaining.lock().expect("latch lock");
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every task counted down.
    pub(crate) fn wait(&self) {
        let mut r = self.remaining.lock().expect("latch lock");
        while *r > 0 {
            r = self.done.wait(r).expect("latch wait");
        }
    }
}

/// Decrements the live-worker counter when a worker thread exits (any
/// path, including unwind).
struct AliveGuard(Arc<AtomicUsize>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The first panic payload of a fork-join round, carried back to the
/// calling thread so it can be `resume_unwind`ed with its original
/// message (a later panic in the same round is dropped — same behaviour
/// as scoped threads, which propagate whichever join hits first).
pub(crate) type PanicSlot = Arc<Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>>;

/// Guard attached to every dispatched task: counts the latch down on
/// drop, so the caller's barrier always releases.
pub(crate) struct TaskGuard {
    pub(crate) latch: Arc<Latch>,
}

impl Drop for TaskGuard {
    fn drop(&mut self) {
        self.latch.count_down();
    }
}

/// Run `job`, catching a panic into `slot` (first payload wins).
pub(crate) fn run_caught<F: FnOnce()>(job: F, slot: &PanicSlot) {
    if let Err(payload) = std::panic::catch_unwind(AssertUnwindSafe(job)) {
        let mut slot = slot.lock().expect("panic slot lock");
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
}

/// The long-lived worker set of one [`crate::exec::ExecEngine`].
#[derive(Debug)]
pub struct WorkerPool {
    senders: Vec<Sender<Task>>,
    handles: Vec<JoinHandle<()>>,
    alive: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `workers` parked threads. This is the only place threads
    /// are ever created — every subsequent call reuses them.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "pool needs at least one worker");
        let alive = Arc::new(AtomicUsize::new(workers));
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = std::sync::mpsc::channel::<Task>();
            let guard_counter = alive.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ada-exec-{w}"))
                .spawn(move || {
                    let _guard = AliveGuard(guard_counter);
                    // Parked in `recv` between fork-join rounds; exits
                    // when the engine drops its sender. Task wrappers
                    // already catch their own panics ([`run_caught`]);
                    // this outer catch is a second belt so a bad task
                    // can never kill the worker.
                    while let Ok(task) = rx.recv() {
                        let _ = std::panic::catch_unwind(AssertUnwindSafe(task));
                    }
                })
                .expect("spawn exec worker");
            senders.push(tx);
            handles.push(handle);
        }
        WorkerPool {
            senders,
            handles,
            alive,
        }
    }

    /// Number of pool workers (excludes the calling thread).
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Live worker-thread count — `workers()` while the pool is up, `0`
    /// after drop has joined them. Exposed so tests can prove the
    /// spawn-once / join-on-drop contract.
    pub fn liveness(&self) -> Arc<AtomicUsize> {
        self.alive.clone()
    }

    /// Hand `tasks` to the workers (non-blocking; completion is the
    /// caller's latch). Task `i` goes to worker `i % workers`, so a
    /// round with at most `workers` tasks maps one task per worker.
    ///
    /// Never panics and never strands a task: if a send fails (a worker
    /// died — possible only through events outside the task protocol,
    /// since task panics are contained), the task runs inline on the
    /// calling thread so its latch still counts down. Stranding one
    /// would leave the caller's barrier waiting forever, and unwinding
    /// here instead would drop borrows that already-dispatched tasks
    /// still reference.
    pub(crate) fn dispatch(&self, tasks: Vec<Task>) {
        let w = self.senders.len();
        for (i, task) in tasks.into_iter().enumerate() {
            if let Err(std::sync::mpsc::SendError(task)) = self.senders[i % w].send(task) {
                task();
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channels wakes every parked worker out of `recv`;
        // joining guarantees no thread outlives the engine.
        self.senders.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_spawns_and_joins_exactly() {
        let pool = WorkerPool::new(3);
        let live = pool.liveness();
        assert_eq!(pool.workers(), 3);
        assert_eq!(live.load(Ordering::SeqCst), 3);
        drop(pool);
        assert_eq!(live.load(Ordering::SeqCst), 0, "drop must join workers");
    }

    #[test]
    fn dispatch_runs_tasks_and_latch_releases() {
        let pool = WorkerPool::new(2);
        let latch = Arc::new(Latch::new(2));
        let hits = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..2)
            .map(|_| {
                let latch = latch.clone();
                let hits = hits.clone();
                Box::new(move || {
                    let _g = TaskGuard { latch };
                    hits.fetch_add(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        pool.dispatch(tasks);
        latch.wait();
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn worker_survives_a_panicking_task_and_payload_is_kept() {
        let pool = WorkerPool::new(1);
        let slot: PanicSlot = Arc::new(Mutex::new(None));
        let latch = Arc::new(Latch::new(1));
        let (l, s) = (latch.clone(), slot.clone());
        pool.dispatch(vec![Box::new(move || {
            let _g = TaskGuard { latch: l };
            run_caught(|| panic!("boom"), &s);
        }) as Task]);
        latch.wait();
        let payload = slot.lock().unwrap().take().expect("payload captured");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // The same worker still serves the next round.
        let latch2 = Arc::new(Latch::new(1));
        let ok = Arc::new(AtomicUsize::new(0));
        let (l2, ok2) = (latch2.clone(), ok.clone());
        pool.dispatch(vec![Box::new(move || {
            let _g = TaskGuard { latch: l2 };
            ok2.fetch_add(1, Ordering::SeqCst);
        }) as Task]);
        latch2.wait();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }
}
