//! A minimal HTTP/1.1 front end over `std::net::TcpListener` — no
//! framework, no dependencies, one thread per connection, one request
//! per connection (`Connection: close`). That is deliberately the
//! simplest protocol shape that supports the service's needs: small
//! JSON request/response bodies plus one long-lived chunked response
//! for metric streaming.
//!
//! Routes:
//!
//! | Method & path              | Effect                                                |
//! |----------------------------|-------------------------------------------------------|
//! | `GET /`                    | Service info (name, jobs, store stats)                |
//! | `GET /healthz`             | Liveness probe                                        |
//! | `POST /jobs`               | Submit a spec (TOML or JSON body, sniffed); query `priority`, `weight`, `seeds` |
//! | `GET /jobs`                | All job statuses                                      |
//! | `GET /jobs/{id}`           | One job status                                        |
//! | `POST /jobs/{id}/cancel`   | Cancel (cell-boundary preemption)                     |
//! | `GET /jobs/{id}/results`   | Results document (deterministic bytes)                |
//! | `GET /jobs/{id}/stream`    | Chunked JSONL event stream (replay + live tail)       |
//! | `GET /scheduler`           | Dispatch gate + dispatch log                          |
//! | `POST /scheduler/pause`    | Close the dispatch gate                               |
//! | `POST /scheduler/resume`   | Open the dispatch gate                                |
//! | `GET /store`               | Result-store statistics                               |
//! | `POST /shutdown`           | Stop the server; `?drain=false` cancels in-flight cells |
//!
//! The module also ships the tiny client half ([`http_request`],
//! [`http_stream_lines`]) that `dbench submit/status/results/stream`
//! and the integration tests use — the same parser exercising both
//! directions keeps the protocol honest without external tooling.

use super::scheduler::Scheduler;
use super::store::ResultStore;
use crate::dbench::{ExperimentSpec, SessionPlan};
use crate::error::{AdaError, Result};
use crate::util::json::Value;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration (the `dbench serve` flags).
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks a free port — tests rely on
    /// this).
    pub addr: String,
    /// Result-store root directory.
    pub store_dir: String,
    /// Concurrent cell workers.
    pub workers: usize,
    /// Start with the dispatch gate closed ([`Scheduler::pause`]);
    /// tests use this to submit multiple jobs before any cell runs.
    pub hold: bool,
}

/// One parsed request.
struct Request {
    method: String,
    path: String,
    query: BTreeMap<String, String>,
    body: Vec<u8>,
}

fn parse_query(raw: &str) -> BTreeMap<String, String> {
    raw.split('&')
        .filter_map(|pair| {
            let (k, v) = pair.split_once('=')?;
            (!k.is_empty()).then(|| (k.to_string(), v.to_string()))
        })
        .collect()
}

fn read_request(stream: &TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| AdaError::Runtime("empty request line".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| AdaError::Runtime("request line missing target".into()))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target.to_string(), BTreeMap::new()),
    };
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().map_err(|_| {
                    AdaError::Runtime(format!("bad Content-Length {value:?}"))
                })?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, query, body })
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Internal Server Error",
    }
}

fn respond(stream: &mut TcpStream, code: u16, content_type: &str, body: &[u8]) {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status_text(code),
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body);
    let _ = stream.flush();
}

fn respond_json(stream: &mut TcpStream, code: u16, v: &Value) {
    respond(stream, code, "application/json", v.to_string().as_bytes());
}

fn error_json(msg: impl Into<String>) -> Value {
    Value::obj(vec![("error", Value::Str(msg.into()))])
}

/// Shared server state.
struct Ctx {
    scheduler: Arc<Scheduler>,
    store: Arc<ResultStore>,
    shutdown: AtomicBool,
    drain: AtomicBool,
    addr: SocketAddr,
}

/// A running server handle: its bound address (query it when binding
/// port 0), plus shutdown/join.
pub struct Server {
    /// The actually-bound address.
    pub addr: SocketAddr,
    ctx: Arc<Ctx>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Stop the server from the owning process: `drain = true` lets
    /// in-flight cells finish and persist, `false` cancels them at the
    /// next iteration boundary. Idempotent with `POST /shutdown`.
    pub fn shutdown(&self, drain: bool) {
        self.ctx.drain.store(drain, Ordering::SeqCst);
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        // Wake the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.ctx.addr);
    }

    /// Wait for the accept loop (and therefore the scheduler workers)
    /// to finish.
    pub fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown(true);
            self.join();
        }
    }
}

/// Bind, spawn the scheduler workers and the accept loop, and return
/// immediately. The server runs until [`Server::shutdown`] or a
/// `POST /shutdown` request.
pub fn start(cfg: &ServeConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| AdaError::Runtime(format!("bind {}: {e}", cfg.addr)))?;
    let addr = listener.local_addr()?;
    let store = Arc::new(ResultStore::open(&cfg.store_dir)?);
    let scheduler = Scheduler::start(Arc::clone(&store), cfg.workers, cfg.hold);
    let ctx = Arc::new(Ctx {
        scheduler,
        store,
        shutdown: AtomicBool::new(false),
        drain: AtomicBool::new(true),
        addr,
    });
    let accept_ctx = Arc::clone(&ctx);
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_ctx.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            let handler_ctx = Arc::clone(&accept_ctx);
            std::thread::spawn(move || handle(handler_ctx, stream));
        }
        accept_ctx
            .scheduler
            .shutdown(accept_ctx.drain.load(Ordering::SeqCst));
    });
    Ok(Server { addr, ctx, accept: Some(accept) })
}

fn handle(ctx: Arc<Ctx>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let req = match read_request(&stream) {
        Ok(r) => r,
        Err(e) => {
            respond_json(&mut stream, 400, &error_json(e.to_string()));
            return;
        }
    };
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", []) => {
            let stats = ctx.store.stats();
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![
                    ("service", Value::Str("dbench".into())),
                    ("jobs", Value::Num(ctx.scheduler.list().len() as f64)),
                    ("paused", Value::Bool(ctx.scheduler.paused())),
                    ("store_objects", Value::Num(stats.objects as f64)),
                ]),
            );
        }
        ("GET", ["healthz"]) => {
            respond_json(&mut stream, 200, &Value::obj(vec![("ok", Value::Bool(true))]));
        }
        ("POST", ["jobs"]) => handle_submit(&ctx, &mut stream, &req),
        ("GET", ["jobs"]) => {
            let list = ctx.scheduler.list().iter().map(|s| s.to_json()).collect();
            respond_json(&mut stream, 200, &Value::obj(vec![("jobs", Value::Arr(list))]));
        }
        ("GET", ["jobs", id]) => match ctx.scheduler.status(id) {
            Some(s) => respond_json(&mut stream, 200, &s.to_json()),
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("POST", ["jobs", id, "cancel"]) => match ctx.scheduler.cancel(id) {
            Some(s) => respond_json(&mut stream, 200, &s.to_json()),
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("GET", ["jobs", id, "results"]) => match ctx.scheduler.job(id) {
            Some(job) => respond_json(&mut stream, 200, &job.results_json()),
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("GET", ["jobs", id, "stream"]) => match ctx.scheduler.job(id) {
            Some(job) => stream_events(&ctx, &mut stream, &job.events),
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("GET", ["scheduler"]) => {
            let log = ctx
                .scheduler
                .dispatch_log()
                .into_iter()
                .map(|(id, cell)| {
                    Value::obj(vec![
                        ("job", Value::Str(id)),
                        ("cell", Value::Num(cell as f64)),
                    ])
                })
                .collect();
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![
                    ("paused", Value::Bool(ctx.scheduler.paused())),
                    ("dispatched", Value::Arr(log)),
                ]),
            );
        }
        ("POST", ["scheduler", "pause"]) => {
            ctx.scheduler.pause();
            respond_json(&mut stream, 200, &Value::obj(vec![("paused", Value::Bool(true))]));
        }
        ("POST", ["scheduler", "resume"]) => {
            ctx.scheduler.resume();
            respond_json(&mut stream, 200, &Value::obj(vec![("paused", Value::Bool(false))]));
        }
        ("GET", ["store"]) => {
            let s = ctx.store.stats();
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![
                    ("objects", Value::Num(s.objects as f64)),
                    ("hits", Value::Num(s.hits as f64)),
                    ("misses", Value::Num(s.misses as f64)),
                ]),
            );
        }
        ("POST", ["shutdown"]) => {
            let drain = req.query.get("drain").map(|v| v != "false").unwrap_or(true);
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![("stopping", Value::Bool(true)), ("drain", Value::Bool(drain))]),
            );
            ctx.drain.store(drain, Ordering::SeqCst);
            ctx.shutdown.store(true, Ordering::SeqCst);
            // Wake the accept loop so it observes the flag.
            let _ = TcpStream::connect(ctx.addr);
        }
        (method, _) => {
            let code = if matches!(method, "GET" | "POST") { 404 } else { 405 };
            respond_json(
                &mut stream,
                code,
                &error_json(format!("no route {} {}", method, req.path)),
            );
        }
    }
}

fn handle_submit(ctx: &Arc<Ctx>, stream: &mut TcpStream, req: &Request) {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => {
            respond_json(stream, 400, &error_json("spec body is not UTF-8"));
            return;
        }
    };
    let parse = |name: &str| -> std::result::Result<Option<f64>, String> {
        match req.query.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("query {name}={raw:?} is not a number")),
        }
    };
    let submitted = ExperimentSpec::from_text(text).and_then(|spec| {
        let mut plan = SessionPlan::from_spec(&spec);
        if let Some(seeds) = req
            .query
            .get("seeds")
            .map(|raw| raw.parse::<usize>().map_err(|_| AdaError::Config(format!("query seeds={raw:?} is not an integer"))))
            .transpose()?
        {
            plan.expand_seeds(seeds);
        }
        let priority = parse("priority").map_err(AdaError::Config)?.unwrap_or(0.0) as i64;
        let weight = parse("weight").map_err(AdaError::Config)?.unwrap_or(1.0);
        ctx.scheduler.submit(spec.name.clone(), priority, weight, plan)
    });
    match submitted {
        Ok(job) => respond_json(
            stream,
            200,
            &Value::obj(vec![
                ("job", Value::Str(job.id.clone())),
                ("cells", Value::Num(job.plan.cells.len() as f64)),
                ("priority", Value::Num(job.priority as f64)),
                ("weight", Value::Num(job.weight)),
            ]),
        ),
        Err(e) => respond_json(stream, 400, &error_json(e.to_string())),
    }
}

/// The chunked JSONL stream: replay everything logged so far, then tail
/// until the job's event log closes (or the server shuts down / the
/// client hangs up — a failed write ends the tail).
fn stream_events(ctx: &Arc<Ctx>, stream: &mut TcpStream, events: &super::stream::EventLog) {
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        return;
    }
    let mut cursor = 0usize;
    loop {
        let (lines, closed) = events.wait_from(cursor, Duration::from_millis(250));
        cursor += lines.len();
        for line in &lines {
            let payload = format!("{line}\n");
            let chunk = format!("{:x}\r\n{payload}\r\n", payload.len());
            if stream.write_all(chunk.as_bytes()).is_err() {
                return;
            }
        }
        let _ = stream.flush();
        if (closed && lines.is_empty()) || ctx.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    let _ = stream.write_all(b"0\r\n\r\n");
    let _ = stream.flush();
}

// ---------------------------------------------------------------------
// Client half — used by `dbench submit/status/results/stream` and the
// integration tests.
// ---------------------------------------------------------------------

fn read_headers(reader: &mut BufReader<TcpStream>) -> Result<(u16, BTreeMap<String, String>)> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let code = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| AdaError::Runtime(format!("bad status line {line:?}")))?;
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }
    Ok((code, headers))
}

fn read_chunked(reader: &mut BufReader<TcpStream>) -> Result<Vec<u8>> {
    let mut body = Vec::new();
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line)?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| AdaError::Runtime(format!("bad chunk size {size_line:?}")))?;
        if size == 0 {
            let mut trailer = String::new();
            let _ = reader.read_line(&mut trailer);
            return Ok(body);
        }
        let mut chunk = vec![0u8; size];
        reader.read_exact(&mut chunk)?;
        body.extend_from_slice(&chunk);
        let mut crlf = [0u8; 2];
        reader.read_exact(&mut crlf)?;
    }
}

/// One HTTP exchange against `addr`: returns `(status, body)`. Handles
/// `Content-Length`, chunked and read-to-EOF bodies.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> Result<(u16, Vec<u8>)> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| AdaError::Runtime(format!("connect {addr}: {e}")))?;
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    let mut writer = stream.try_clone()?;
    let payload = body.unwrap_or(&[]);
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    writer.write_all(head.as_bytes())?;
    writer.write_all(payload)?;
    writer.flush()?;
    let mut reader = BufReader::new(stream);
    let (code, headers) = read_headers(&mut reader)?;
    let body = if headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false)
    {
        read_chunked(&mut reader)?
    } else if let Some(len) = headers.get("content-length") {
        let len: usize = len
            .parse()
            .map_err(|_| AdaError::Runtime(format!("bad Content-Length {len:?}")))?;
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        buf
    } else {
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        buf
    };
    Ok((code, body))
}

/// GET `path` and feed each streamed line to `each` as it arrives
/// (chunked framing stripped). Returns the response status.
pub fn http_stream_lines(
    addr: &str,
    path: &str,
    mut each: impl FnMut(&str),
) -> Result<u16> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| AdaError::Runtime(format!("connect {addr}: {e}")))?;
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    let mut writer = stream.try_clone()?;
    let head =
        format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    writer.write_all(head.as_bytes())?;
    writer.flush()?;
    let mut reader = BufReader::new(stream);
    let (code, headers) = read_headers(&mut reader)?;
    let chunked = headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false);
    let mut partial = String::new();
    let mut feed = |partial: &mut String, each: &mut dyn FnMut(&str)| {
        while let Some(pos) = partial.find('\n') {
            let line: String = partial.drain(..=pos).collect();
            let line = line.trim_end();
            if !line.is_empty() {
                each(line);
            }
        }
    };
    if chunked {
        // Decode chunk by chunk so lines reach the callback as they
        // arrive — the live-tail path.
        loop {
            let mut size_line = String::new();
            reader.read_line(&mut size_line)?;
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| AdaError::Runtime(format!("bad chunk size {size_line:?}")))?;
            if size == 0 {
                break;
            }
            let mut chunk = vec![0u8; size];
            reader.read_exact(&mut chunk)?;
            partial.push_str(&String::from_utf8_lossy(&chunk));
            feed(&mut partial, &mut each);
            let mut crlf = [0u8; 2];
            reader.read_exact(&mut crlf)?;
        }
    } else {
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        partial.push_str(&String::from_utf8_lossy(&buf));
        feed(&mut partial, &mut each);
    }
    let tail = partial.trim_end();
    if !tail.is_empty() {
        each(tail);
    }
    Ok(code)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_strings_parse() {
        let q = parse_query("priority=5&weight=2.5&drain=false");
        assert_eq!(q.get("priority").map(String::as_str), Some("5"));
        assert_eq!(q.get("weight").map(String::as_str), Some("2.5"));
        assert_eq!(q.get("drain").map(String::as_str), Some("false"));
        assert!(parse_query("").is_empty());
        assert!(parse_query("novalue").is_empty());
    }

    #[test]
    fn status_lines_cover_the_codes_in_use() {
        for code in [200u16, 400, 404, 405] {
            assert!(!status_text(code).is_empty());
        }
        assert_eq!(status_text(500), "Internal Server Error");
    }
}
