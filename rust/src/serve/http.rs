//! A minimal HTTP/1.1 front end over `std::net::TcpListener` — no
//! framework, no dependencies, one thread per connection, one request
//! per connection (`Connection: close`). That is deliberately the
//! simplest protocol shape that supports the service's needs: small
//! JSON request/response bodies plus one long-lived chunked response
//! for metric streaming.
//!
//! Routes:
//!
//! | Method & path              | Effect                                                |
//! |----------------------------|-------------------------------------------------------|
//! | `GET /`                    | Service info (name, jobs, store stats)                |
//! | `GET /healthz`             | Liveness probe                                        |
//! | `POST /jobs`               | Submit a spec (TOML or JSON body, sniffed); query `priority`, `weight`, `seeds`, `retries`, `deadline_s`, `idempotent` |
//! | `GET /jobs`                | All job statuses                                      |
//! | `GET /jobs/{id}`           | One job status                                        |
//! | `POST /jobs/{id}/cancel`   | Cancel (cell-boundary preemption)                     |
//! | `GET /jobs/{id}/results`   | Results document (deterministic bytes)                |
//! | `GET /jobs/{id}/stream`    | Chunked JSONL event stream (replay + live tail); `?from=N` skips the first N events |
//! | `GET /scheduler`           | Dispatch gate + dispatch log                          |
//! | `POST /scheduler/pause`    | Close the dispatch gate                               |
//! | `POST /scheduler/resume`   | Open the dispatch gate                                |
//! | `GET /store`               | Result-store statistics (incl. quarantined objects)   |
//! | `POST /shutdown`           | Stop the server; `?drain=false` cancels in-flight cells |
//!
//! The module also ships the tiny client half ([`http_request`],
//! [`http_stream_lines`]) that `dbench submit/status/results/stream`
//! and the integration tests use — the same parser exercising both
//! directions keeps the protocol honest without external tooling.
//!
//! ## Robustness
//!
//! The server bounds itself: at most [`ServeConfig::max_conns`]
//! concurrent connection threads (excess connections are shed with
//! `503` + `Retry-After: 1` before any request parsing), and a client
//! that stalls mid-upload past the read timeout gets a JSON `408`
//! instead of a silently closed socket. The client half retries: the
//! `_with` variants take a [`ClientConfig`] with connect/read timeouts
//! and capped deterministic-backoff retries — only for requests that
//! are safe to repeat (GETs, never-transmitted writes, and any `503`) —
//! and a dropped event stream re-attaches with `?from=` set past the
//! events already delivered, so the caller's closure sees each event
//! exactly once.

use super::scheduler::{Scheduler, SchedulerConfig, SubmitOptions};
use super::store::ResultStore;
use crate::error::{AdaError, Result};
use crate::util::json::Value;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration (the `dbench serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks a free port — tests rely on
    /// this).
    pub addr: String,
    /// Result-store root directory.
    pub store_dir: String,
    /// Concurrent cell workers.
    pub workers: usize,
    /// Start with the dispatch gate closed ([`Scheduler::pause`]);
    /// tests use this to submit multiple jobs before any cell runs.
    pub hold: bool,
    /// Journal submissions under `<store>/journal/` and replay them on
    /// start (on by default — the durability contract).
    pub journal: bool,
    /// Default transient-failure retries per cell.
    pub retries: usize,
    /// Default per-cell wall-clock deadline in seconds (0 = none).
    pub deadline_s: f64,
    /// Maximum concurrent connection threads; excess connections are
    /// shed with `503` + `Retry-After`.
    pub max_conns: usize,
    /// Per-connection read timeout in seconds (a stalled upload gets a
    /// JSON `408`).
    pub read_timeout_s: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7070".into(),
            store_dir: "dbench_store".into(),
            workers: 1,
            hold: false,
            journal: true,
            retries: 0,
            deadline_s: 0.0,
            max_conns: 64,
            read_timeout_s: 30.0,
        }
    }
}

/// One parsed request.
struct Request {
    method: String,
    path: String,
    query: BTreeMap<String, String>,
    body: Vec<u8>,
}

fn parse_query(raw: &str) -> BTreeMap<String, String> {
    raw.split('&')
        .filter_map(|pair| {
            let (k, v) = pair.split_once('=')?;
            (!k.is_empty()).then(|| (k.to_string(), v.to_string()))
        })
        .collect()
}

fn read_request(stream: &TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| AdaError::Runtime("empty request line".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| AdaError::Runtime("request line missing target".into()))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target.to_string(), BTreeMap::new()),
    };
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().map_err(|_| {
                    AdaError::Runtime(format!("bad Content-Length {value:?}"))
                })?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, query, body })
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

fn respond(stream: &mut TcpStream, code: u16, content_type: &str, body: &[u8]) {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status_text(code),
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body);
    let _ = stream.flush();
}

fn respond_json(stream: &mut TcpStream, code: u16, v: &Value) {
    respond(stream, code, "application/json", v.to_string().as_bytes());
}

fn error_json(msg: impl Into<String>) -> Value {
    Value::obj(vec![("error", Value::Str(msg.into()))])
}

/// Shared server state.
struct Ctx {
    scheduler: Arc<Scheduler>,
    store: Arc<ResultStore>,
    shutdown: AtomicBool,
    drain: AtomicBool,
    addr: SocketAddr,
    active: AtomicUsize,
    max_conns: usize,
    read_timeout: Duration,
}

/// RAII connection-slot guard: decrements the active count however the
/// handler thread exits (including panics).
struct ConnSlot(Arc<Ctx>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running server handle: its bound address (query it when binding
/// port 0), plus shutdown/join.
pub struct Server {
    /// The actually-bound address.
    pub addr: SocketAddr,
    ctx: Arc<Ctx>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Stop the server from the owning process: `drain = true` lets
    /// in-flight cells finish and persist, `false` cancels them at the
    /// next iteration boundary. Idempotent with `POST /shutdown`.
    pub fn shutdown(&self, drain: bool) {
        self.ctx.drain.store(drain, Ordering::SeqCst);
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        // Wake the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.ctx.addr);
    }

    /// Wait for the accept loop (and therefore the scheduler workers)
    /// to finish.
    pub fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown(true);
            self.join();
        }
    }
}

/// Bind, spawn the scheduler workers and the accept loop, and return
/// immediately. The server runs until [`Server::shutdown`] or a
/// `POST /shutdown` request.
pub fn start(cfg: &ServeConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| AdaError::Runtime(format!("bind {}: {e}", cfg.addr)))?;
    let addr = listener.local_addr()?;
    let store = Arc::new(ResultStore::open(&cfg.store_dir)?);
    let scheduler = Scheduler::start_cfg(
        Arc::clone(&store),
        SchedulerConfig {
            workers: cfg.workers,
            paused: cfg.hold,
            journal: cfg.journal,
            retries: cfg.retries,
            deadline_s: cfg.deadline_s,
        },
    )?;
    let ctx = Arc::new(Ctx {
        scheduler,
        store,
        shutdown: AtomicBool::new(false),
        drain: AtomicBool::new(true),
        addr,
        active: AtomicUsize::new(0),
        max_conns: cfg.max_conns.max(1),
        read_timeout: Duration::from_secs_f64(cfg.read_timeout_s.max(0.01)),
    });
    let accept_ctx = Arc::clone(&ctx);
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_ctx.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let mut stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            // Load shedding: beyond the cap, answer 503 inline (cheap,
            // no thread, no request parsing) and move on.
            if accept_ctx.active.fetch_add(1, Ordering::SeqCst) >= accept_ctx.max_conns {
                accept_ctx.active.fetch_sub(1, Ordering::SeqCst);
                let body = error_json("server is at its connection limit").to_string();
                let head = format!(
                    "HTTP/1.1 503 Service Unavailable\r\nContent-Type: application/json\r\nContent-Length: {}\r\nRetry-After: 1\r\nConnection: close\r\n\r\n",
                    body.len()
                );
                let _ = stream.write_all(head.as_bytes());
                let _ = stream.write_all(body.as_bytes());
                let _ = stream.flush();
                continue;
            }
            let handler_ctx = Arc::clone(&accept_ctx);
            std::thread::spawn(move || {
                let _slot = ConnSlot(Arc::clone(&handler_ctx));
                handle(handler_ctx, stream);
            });
        }
        accept_ctx
            .scheduler
            .shutdown(accept_ctx.drain.load(Ordering::SeqCst));
    });
    Ok(Server { addr, ctx, accept: Some(accept) })
}

fn handle(ctx: Arc<Ctx>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(ctx.read_timeout));
    let req = match read_request(&stream) {
        Ok(r) => r,
        Err(e) => {
            // A stalled read (client wedged mid-upload) is the client's
            // timeout, not a malformed request: say so with 408 instead
            // of silently dropping the socket.
            let timed_out = matches!(
                &e,
                AdaError::Io(io) if matches!(
                    io.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                )
            );
            if timed_out {
                respond_json(
                    &mut stream,
                    408,
                    &error_json("timed out reading the request"),
                );
            } else {
                respond_json(&mut stream, 400, &error_json(e.to_string()));
            }
            return;
        }
    };
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", []) => {
            let stats = ctx.store.stats();
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![
                    ("service", Value::Str("dbench".into())),
                    ("jobs", Value::Num(ctx.scheduler.list().len() as f64)),
                    ("paused", Value::Bool(ctx.scheduler.paused())),
                    ("store_objects", Value::Num(stats.objects as f64)),
                ]),
            );
        }
        ("GET", ["healthz"]) => {
            respond_json(&mut stream, 200, &Value::obj(vec![("ok", Value::Bool(true))]));
        }
        ("POST", ["jobs"]) => handle_submit(&ctx, &mut stream, &req),
        ("GET", ["jobs"]) => {
            let list = ctx.scheduler.list().iter().map(|s| s.to_json()).collect();
            respond_json(&mut stream, 200, &Value::obj(vec![("jobs", Value::Arr(list))]));
        }
        ("GET", ["jobs", id]) => match ctx.scheduler.status(id) {
            Some(s) => respond_json(&mut stream, 200, &s.to_json()),
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("POST", ["jobs", id, "cancel"]) => match ctx.scheduler.cancel(id) {
            Some(s) => respond_json(&mut stream, 200, &s.to_json()),
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("GET", ["jobs", id, "results"]) => match ctx.scheduler.job(id) {
            Some(job) => respond_json(&mut stream, 200, &job.results_json()),
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("GET", ["jobs", id, "stream"]) => match ctx.scheduler.job(id) {
            Some(job) => {
                let from = req
                    .query
                    .get("from")
                    .and_then(|raw| raw.parse::<usize>().ok())
                    .unwrap_or(0);
                stream_events(&ctx, &mut stream, &job.events, from);
            }
            None => respond_json(&mut stream, 404, &error_json(format!("unknown job {id}"))),
        },
        ("GET", ["scheduler"]) => {
            let log = ctx
                .scheduler
                .dispatch_log()
                .into_iter()
                .map(|(id, cell)| {
                    Value::obj(vec![
                        ("job", Value::Str(id)),
                        ("cell", Value::Num(cell as f64)),
                    ])
                })
                .collect();
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![
                    ("paused", Value::Bool(ctx.scheduler.paused())),
                    ("dispatched", Value::Arr(log)),
                ]),
            );
        }
        ("POST", ["scheduler", "pause"]) => {
            ctx.scheduler.pause();
            respond_json(&mut stream, 200, &Value::obj(vec![("paused", Value::Bool(true))]));
        }
        ("POST", ["scheduler", "resume"]) => {
            ctx.scheduler.resume();
            respond_json(&mut stream, 200, &Value::obj(vec![("paused", Value::Bool(false))]));
        }
        ("GET", ["store"]) => {
            let s = ctx.store.stats();
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![
                    ("objects", Value::Num(s.objects as f64)),
                    ("hits", Value::Num(s.hits as f64)),
                    ("misses", Value::Num(s.misses as f64)),
                    ("quarantined", Value::Num(s.quarantined as f64)),
                ]),
            );
        }
        ("POST", ["shutdown"]) => {
            let drain = req.query.get("drain").map(|v| v != "false").unwrap_or(true);
            respond_json(
                &mut stream,
                200,
                &Value::obj(vec![("stopping", Value::Bool(true)), ("drain", Value::Bool(drain))]),
            );
            ctx.drain.store(drain, Ordering::SeqCst);
            ctx.shutdown.store(true, Ordering::SeqCst);
            // Wake the accept loop so it observes the flag.
            let _ = TcpStream::connect(ctx.addr);
        }
        (method, _) => {
            let code = if matches!(method, "GET" | "POST") { 404 } else { 405 };
            respond_json(
                &mut stream,
                code,
                &error_json(format!("no route {} {}", method, req.path)),
            );
        }
    }
}

fn handle_submit(ctx: &Arc<Ctx>, stream: &mut TcpStream, req: &Request) {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => {
            respond_json(stream, 400, &error_json("spec body is not UTF-8"));
            return;
        }
    };
    let parse = |name: &str| -> std::result::Result<Option<f64>, AdaError> {
        match req.query.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<f64>()
                .map(Some)
                .map_err(|_| AdaError::Config(format!("query {name}={raw:?} is not a number"))),
        }
    };
    let submitted = (|| {
        let opts = SubmitOptions {
            priority: parse("priority")?.unwrap_or(0.0) as i64,
            weight: parse("weight")?.unwrap_or(1.0),
            seeds: req
                .query
                .get("seeds")
                .map(|raw| {
                    raw.parse::<usize>().map_err(|_| {
                        AdaError::Config(format!("query seeds={raw:?} is not an integer"))
                    })
                })
                .transpose()?
                .unwrap_or(0),
            idempotent: req
                .query
                .get("idempotent")
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false),
            retries: parse("retries")?.map(|r| r.max(0.0) as usize),
            deadline_s: parse("deadline_s")?,
        };
        ctx.scheduler.submit_spec(text, &opts)
    })();
    match submitted {
        Ok(job) => respond_json(
            stream,
            200,
            &Value::obj(vec![
                ("job", Value::Str(job.id.clone())),
                ("cells", Value::Num(job.plan.cells.len() as f64)),
                ("priority", Value::Num(job.priority as f64)),
                ("weight", Value::Num(job.weight)),
            ]),
        ),
        Err(e) => respond_json(stream, 400, &error_json(e.to_string())),
    }
}

/// The chunked JSONL stream: replay everything logged from event
/// `from` onward, then tail until the job's event log closes (or the
/// server shuts down / the client hangs up — a failed write ends the
/// tail). The `from` cursor is what lets a dropped client re-attach
/// without duplicate events.
fn stream_events(
    ctx: &Arc<Ctx>,
    stream: &mut TcpStream,
    events: &super::stream::EventLog,
    from: usize,
) {
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        return;
    }
    let mut cursor = from;
    loop {
        let (lines, closed) = events.wait_from(cursor, Duration::from_millis(250));
        cursor += lines.len();
        for line in &lines {
            let payload = format!("{line}\n");
            let chunk = format!("{:x}\r\n{payload}\r\n", payload.len());
            if stream.write_all(chunk.as_bytes()).is_err() {
                return;
            }
        }
        let _ = stream.flush();
        if (closed && lines.is_empty()) || ctx.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    let _ = stream.write_all(b"0\r\n\r\n");
    let _ = stream.flush();
}

// ---------------------------------------------------------------------
// Client half — used by `dbench submit/status/results/stream` and the
// integration tests.
// ---------------------------------------------------------------------

fn read_headers(reader: &mut BufReader<TcpStream>) -> Result<(u16, BTreeMap<String, String>)> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let code = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| AdaError::Runtime(format!("bad status line {line:?}")))?;
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }
    Ok((code, headers))
}

fn read_chunked(reader: &mut BufReader<TcpStream>) -> Result<Vec<u8>> {
    let mut body = Vec::new();
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line)?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| AdaError::Runtime(format!("bad chunk size {size_line:?}")))?;
        if size == 0 {
            let mut trailer = String::new();
            let _ = reader.read_line(&mut trailer);
            return Ok(body);
        }
        let mut chunk = vec![0u8; size];
        reader.read_exact(&mut chunk)?;
        body.extend_from_slice(&chunk);
        let mut crlf = [0u8; 2];
        reader.read_exact(&mut crlf)?;
    }
}

/// Client-side timeouts and retry policy for [`http_request_with`] /
/// [`http_stream_lines_with`].
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// Socket read timeout.
    pub read_timeout: Duration,
    /// Retry attempts beyond the first (0 = one try).
    pub retries: usize,
    /// Base backoff delay; grows exponentially per attempt with
    /// deterministic jitter, capped at 2 s.
    pub backoff: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(120),
            retries: 3,
            backoff: Duration::from_millis(100),
        }
    }
}

/// Deterministic jittered client backoff — same construction as the
/// scheduler's retry delay: a pure hash of `(key, attempt)` scales the
/// exponential base into [0.5, 1.5), capped at 2 s.
fn client_backoff(key: &str, attempt: usize, base: Duration) -> Duration {
    let h = u64::from_str_radix(
        &super::store::content_hash(&format!("{key}#{attempt}"))[..16],
        16,
    )
    .unwrap_or(0);
    let jitter = 0.5 + (h % 1024) as f64 / 1024.0;
    let scaled =
        base.as_secs_f64() * (1u64 << attempt.min(6)) as f64 * jitter;
    Duration::from_secs_f64(scaled.min(2.0))
}

fn connect_with(addr: &str, cfg: &ClientConfig) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| AdaError::Runtime(format!("resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| AdaError::Runtime(format!("resolve {addr}: no addresses")))?;
    let stream = TcpStream::connect_timeout(&sock, cfg.connect_timeout)
        .map_err(|e| AdaError::Runtime(format!("connect {addr}: {e}")))?;
    stream.set_read_timeout(Some(cfg.read_timeout))?;
    Ok(stream)
}

/// One HTTP exchange, no retries. `sent` flips to true the moment any
/// request bytes hit the wire — the fact the retry policy needs to
/// decide whether a failed non-GET is safe to repeat.
fn request_once(
    addr: &str,
    method: &str,
    path: &str,
    payload: &[u8],
    cfg: &ClientConfig,
    sent: &mut bool,
) -> Result<(u16, Vec<u8>)> {
    let stream = connect_with(addr, cfg)?;
    let mut writer = stream.try_clone()?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    *sent = true;
    writer.write_all(head.as_bytes())?;
    writer.write_all(payload)?;
    writer.flush()?;
    let mut reader = BufReader::new(stream);
    let (code, headers) = read_headers(&mut reader)?;
    let body = if headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false)
    {
        read_chunked(&mut reader)?
    } else if let Some(len) = headers.get("content-length") {
        let len: usize = len
            .parse()
            .map_err(|_| AdaError::Runtime(format!("bad Content-Length {len:?}")))?;
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        buf
    } else {
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        buf
    };
    Ok((code, body))
}

/// One HTTP exchange against `addr` with the default [`ClientConfig`]:
/// returns `(status, body)`. Handles `Content-Length`, chunked and
/// read-to-EOF bodies.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> Result<(u16, Vec<u8>)> {
    http_request_with(addr, method, path, body, &ClientConfig::default())
}

/// [`http_request`] with explicit timeouts and retries. Retries are
/// applied only when repeating is safe: any transport error on a GET,
/// a transport error on a write whose bytes never reached the wire, or
/// a `503` shed response (the server refused before reading the
/// request). A write that failed mid-flight is returned as the error —
/// the caller decides (idempotent submits can simply resubmit).
pub fn http_request_with(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    cfg: &ClientConfig,
) -> Result<(u16, Vec<u8>)> {
    let payload = body.unwrap_or(&[]);
    let mut attempt = 0usize;
    loop {
        let mut sent = false;
        let outcome = request_once(addr, method, path, payload, cfg, &mut sent);
        let retryable = match &outcome {
            Ok((503, _)) => true,
            Ok(_) => false,
            Err(_) => method.eq_ignore_ascii_case("GET") || !sent,
        };
        if !retryable || attempt >= cfg.retries {
            return outcome;
        }
        attempt += 1;
        std::thread::sleep(client_backoff(path, attempt, cfg.backoff));
    }
}

/// GET `path` and feed each streamed line to `each` with the default
/// [`ClientConfig`]. Returns the response status.
pub fn http_stream_lines(
    addr: &str,
    path: &str,
    each: impl FnMut(&str),
) -> Result<u16> {
    http_stream_lines_with(addr, path, each, &ClientConfig::default())
}

/// One streaming attempt. Lines are fed to the callback only on a 200
/// (an error body is drained, not delivered); `delivered` counts the
/// lines handed over across the whole call so a re-attach can resume
/// past them.
fn stream_once(
    addr: &str,
    path: &str,
    cfg: &ClientConfig,
    each: &mut dyn FnMut(&str),
    delivered: &mut usize,
) -> Result<u16> {
    let stream = connect_with(addr, cfg)?;
    let mut writer = stream.try_clone()?;
    let head =
        format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    writer.write_all(head.as_bytes())?;
    writer.flush()?;
    let mut reader = BufReader::new(stream);
    let (code, headers) = read_headers(&mut reader)?;
    let deliver = code == 200;
    let chunked = headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false);
    let mut partial = String::new();
    let mut feed = |partial: &mut String,
                    each: &mut dyn FnMut(&str),
                    delivered: &mut usize| {
        while let Some(pos) = partial.find('\n') {
            let line: String = partial.drain(..=pos).collect();
            let line = line.trim_end();
            if !line.is_empty() && deliver {
                each(line);
                *delivered += 1;
            }
        }
    };
    if chunked {
        // Decode chunk by chunk so lines reach the callback as they
        // arrive — the live-tail path.
        loop {
            let mut size_line = String::new();
            reader.read_line(&mut size_line)?;
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| AdaError::Runtime(format!("bad chunk size {size_line:?}")))?;
            if size == 0 {
                break;
            }
            let mut chunk = vec![0u8; size];
            reader.read_exact(&mut chunk)?;
            partial.push_str(&String::from_utf8_lossy(&chunk));
            feed(&mut partial, each, delivered);
            let mut crlf = [0u8; 2];
            reader.read_exact(&mut crlf)?;
        }
    } else {
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        partial.push_str(&String::from_utf8_lossy(&buf));
        feed(&mut partial, each, delivered);
    }
    let tail = partial.trim_end();
    if !tail.is_empty() && deliver {
        each(tail);
        *delivered += 1;
    }
    Ok(code)
}

/// [`http_stream_lines`] with explicit timeouts and retries. A dropped
/// stream (connect failure, mid-stream transport error, or a `503`
/// shed) re-attaches with `?from=` advanced past the lines already
/// delivered — the server's event-cursor replay makes the combined
/// stream exactly-once from the callback's point of view.
pub fn http_stream_lines_with(
    addr: &str,
    path: &str,
    mut each: impl FnMut(&str),
    cfg: &ClientConfig,
) -> Result<u16> {
    // Honour any cursor already present in the caller's path.
    let (bare, base_from) = match path.split_once('?') {
        Some((p, q)) => {
            let query = parse_query(q);
            let from = query
                .get("from")
                .and_then(|raw| raw.parse::<usize>().ok())
                .unwrap_or(0);
            let rest: Vec<String> = q
                .split('&')
                .filter(|pair| !pair.starts_with("from="))
                .map(str::to_string)
                .collect();
            let rest = rest.join("&");
            if rest.is_empty() {
                (p.to_string(), from)
            } else {
                (format!("{p}?{rest}"), from)
            }
        }
        None => (path.to_string(), 0),
    };
    let mut delivered = 0usize;
    let mut attempt = 0usize;
    loop {
        let from = base_from + delivered;
        let attempt_path = if bare.contains('?') {
            format!("{bare}&from={from}")
        } else {
            format!("{bare}?from={from}")
        };
        let outcome = stream_once(addr, &attempt_path, cfg, &mut each, &mut delivered);
        let retryable = matches!(&outcome, Err(_) | Ok(503));
        if !retryable || attempt >= cfg.retries {
            return outcome;
        }
        attempt += 1;
        std::thread::sleep(client_backoff(&bare, attempt, cfg.backoff));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_strings_parse() {
        let q = parse_query("priority=5&weight=2.5&drain=false");
        assert_eq!(q.get("priority").map(String::as_str), Some("5"));
        assert_eq!(q.get("weight").map(String::as_str), Some("2.5"));
        assert_eq!(q.get("drain").map(String::as_str), Some("false"));
        assert!(parse_query("").is_empty());
        assert!(parse_query("novalue").is_empty());
    }

    #[test]
    fn status_lines_cover_the_codes_in_use() {
        for code in [200u16, 400, 404, 405, 408, 503] {
            assert!(!status_text(code).is_empty());
        }
        assert_eq!(status_text(408), "Request Timeout");
        assert_eq!(status_text(503), "Service Unavailable");
        assert_eq!(status_text(500), "Internal Server Error");
    }

    #[test]
    fn client_backoff_is_deterministic_and_capped() {
        let base = Duration::from_millis(100);
        let a = client_backoff("/jobs/j1/stream", 1, base);
        assert_eq!(a, client_backoff("/jobs/j1/stream", 1, base));
        assert_ne!(a, client_backoff("/jobs/j1/stream", 2, base));
        assert!(client_backoff("/x", 40, base) <= Duration::from_secs(2));
    }
}
