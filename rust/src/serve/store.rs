//! The content-addressed result store: one canonical read/write module
//! for finished [`CellResult`]s, keyed by the cell
//! [fingerprint](crate::dbench::fingerprint) that already guards the
//! CLI's `--resume-dir` caches.
//!
//! Layout under the store root:
//!
//! ```text
//! <root>/objects/<hh>/<hash>.json     # hh = first two hex digits
//! ```
//!
//! where `<hash>` is [`content_hash`] of the fingerprint string. Each
//! object is the [`CellResult::to_json`] document plus a `fingerprint`
//! field, and a read validates that embedded fingerprint against the
//! requested one — a (vanishingly unlikely) hash collision, a truncated
//! write or a hand-edited file all degrade to a cache miss, never to
//! wrong results.
//!
//! The store also **reads the legacy flat layout** the resume pipeline
//! used before this module existed (`<root>/cell_NNNN_<scale>_<key>.json`):
//! a legacy hit is validated the same way, migrated into the
//! content-addressed layout, and served — so pre-existing `--resume-dir`
//! trees keep working with zero re-runs. New writes only ever go to the
//! content-addressed layout.
//!
//! Both the CLI (`SessionPlan::run_cell_plan`) and the experiment
//! service (`serve::Scheduler`) go through this type, which is what
//! makes a server-side cache hit and a CLI resume hit the same bytes.
//!
//! ## Crash safety and quarantine
//!
//! Writes are atomic: the document goes to a unique temp file in the
//! destination shard, is fsynced, and is renamed into place — a crash
//! (even `kill -9`) mid-save leaves either the old object or the new
//! one, never a torn file at the object path. A content-addressed
//! object that *does* fail validation on read (truncated by an older
//! writer, bit rot, a hand edit) is **quarantined**: renamed to
//! `<hash>.corrupt` so it can never be served, counted in
//! [`StoreStats::quarantined`], and the cell recomputes as a plain
//! miss. Legacy flat-layout files are exempt — a fingerprint mismatch
//! there is ordinary staleness, not corruption.

use crate::dbench::CellResult;
use crate::error::Result;
use crate::util::json::Value;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// 128-bit content hash of a fingerprint string, as 32 lowercase hex
/// digits: two independent FNV-1a lanes (different offset bases), each
/// passed through the SplitMix64 finalizer to mix the sparse FNV state.
/// Pure std, stable across platforms and releases — object paths are
/// part of the on-disk format.
pub fn content_hash(fingerprint: &str) -> String {
    fn lane(seed: u64, bytes: &[u8]) -> u64 {
        let mut h = seed;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        // SplitMix64 finalizer.
        h ^= h >> 30;
        h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
        h ^= h >> 27;
        h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
        h ^= h >> 31;
        h
    }
    let bytes = fingerprint.as_bytes();
    format!(
        "{:016x}{:016x}",
        lane(0xcbf2_9ce4_8422_2325, bytes),
        lane(0x9e37_79b9_7f4a_7c15, bytes)
    )
}

/// Hit/miss counters of one store handle (served from memory — cheap
/// enough for a per-request stats endpoint).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    /// Objects currently on disk (counted at call time).
    pub objects: usize,
    /// Loads served from the store since this handle opened.
    pub hits: u64,
    /// Loads that found nothing (and triggered a cell run).
    pub misses: u64,
    /// `*.corrupt` files currently on disk — objects that failed
    /// validation and were quarantined instead of served.
    pub quarantined: usize,
}

/// A content-addressed store of finished cells rooted at one directory.
/// All methods take `&self`; the handle is shared freely across the
/// scheduler's workers (counters are atomic, and concurrent writers of
/// the *same* fingerprint write identical bytes by construction).
pub struct ResultStore {
    root: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    tmp_seq: AtomicU64,
}

impl ResultStore {
    /// Open (creating if needed) the store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        Ok(ResultStore {
            root,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
        })
    }

    /// The store root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Where the object for `fingerprint` lives (whether or not it
    /// exists yet).
    pub fn object_path(&self, fingerprint: &str) -> PathBuf {
        let hash = content_hash(fingerprint);
        self.root.join("objects").join(&hash[..2]).join(format!("{hash}.json"))
    }

    /// Load the result for `fingerprint`, if stored. `legacy_name`
    /// optionally names a flat-layout file (the pre-store
    /// `cell_NNNN_<scale>_<key>.json` convention) to fall back to; a
    /// validated legacy hit is migrated into the content-addressed
    /// layout on the way out. Returns `None` — and counts a miss — on
    /// absence, fingerprint mismatch or any parse failure. A present
    /// but invalid content-addressed object is quarantined (renamed
    /// `*.corrupt`) so the recomputed result can be stored cleanly.
    pub fn load(&self, fingerprint: &str, legacy_name: Option<&str>) -> Option<CellResult> {
        let path = self.object_path(fingerprint);
        if let Some(result) = read_tagged(&path, fingerprint) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(result);
        }
        if path.exists() {
            // The object exists but failed validation — corruption,
            // never a legitimate state for a content-addressed path.
            let _ = std::fs::rename(&path, path.with_extension("corrupt"));
        }
        if let Some(name) = legacy_name {
            if let Some(result) = read_tagged(&self.root.join(name), fingerprint) {
                // Migration shim: serve the legacy bytes and promote
                // them so the next read is content-addressed.
                let _ = self.save(fingerprint, &result);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(result);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Whether a (validated) result for `fingerprint` is present,
    /// without touching the hit/miss counters.
    pub fn contains(&self, fingerprint: &str, legacy_name: Option<&str>) -> bool {
        read_tagged(&self.object_path(fingerprint), fingerprint).is_some()
            || legacy_name
                .map(|name| read_tagged(&self.root.join(name), fingerprint).is_some())
                .unwrap_or(false)
    }

    /// Persist `result` under `fingerprint`, returning the object path.
    /// The write is crash-atomic: unique temp file in the destination
    /// shard, fsync, rename into place (plus a best-effort directory
    /// fsync so the rename itself survives power loss).
    pub fn save(&self, fingerprint: &str, result: &CellResult) -> Result<PathBuf> {
        use std::io::Write;
        let path = self.object_path(fingerprint);
        let parent = path.parent().expect("object path has a shard dir");
        std::fs::create_dir_all(parent)?;
        let tmp = parent.join(format!(
            "{}.tmp.{}.{}",
            path.file_name().expect("object file name").to_string_lossy(),
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed),
        ));
        {
            let mut out = std::fs::File::create(&tmp)?;
            out.write_all(tagged_json(fingerprint, result).to_string().as_bytes())?;
            out.sync_all()?;
        }
        std::fs::rename(&tmp, &path)?;
        let _ = std::fs::File::open(parent).and_then(|d| d.sync_all());
        Ok(path)
    }

    /// Current statistics (object and quarantine counts walk the
    /// `objects/` tree; temp files in flight are not counted).
    pub fn stats(&self) -> StoreStats {
        let mut objects = 0;
        let mut quarantined = 0;
        if let Ok(shards) = std::fs::read_dir(self.root.join("objects")) {
            for shard in shards.flatten() {
                if let Ok(entries) = std::fs::read_dir(shard.path()) {
                    for entry in entries.flatten() {
                        match entry.path().extension().and_then(|e| e.to_str()) {
                            Some("json") => objects += 1,
                            Some("corrupt") => quarantined += 1,
                            _ => {}
                        }
                    }
                }
            }
        }
        StoreStats {
            objects,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            quarantined,
        }
    }
}

/// The persisted document: [`CellResult::to_json`] plus the
/// `fingerprint` that decides whether a later read may reuse it. (The
/// same shape the legacy flat layout used, so old files parse here
/// unchanged.)
pub fn tagged_json(fingerprint: &str, result: &CellResult) -> Value {
    let mut v = result.to_json();
    if let Value::Obj(map) = &mut v {
        map.insert("fingerprint".to_string(), Value::Str(fingerprint.to_string()));
    }
    v
}

/// Read + validate one persisted cell document; `None` on a missing /
/// unparseable file or a fingerprint mismatch.
fn read_tagged(path: &Path, fingerprint: &str) -> Option<CellResult> {
    let text = std::fs::read_to_string(path).ok()?;
    let v = Value::parse(&text).ok()?;
    if v.str_field("fingerprint").ok()? != fingerprint {
        return None;
    }
    CellResult::from_json(&v).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EvalResult, RunSummary};
    use crate::metrics::RunRecorder;

    fn result(metric: f64) -> CellResult {
        CellResult {
            scale: 4,
            flavor: "D_ring".into(),
            recorder: RunRecorder::in_memory("D_ring"),
            summary: RunSummary {
                flavor: "D_ring".into(),
                final_eval: EvalResult { loss: 0.5, metric },
                diverged: false,
                bytes_per_node: 64,
                early_gini: 0.1,
                late_gini: 0.05,
            },
        }
    }

    #[test]
    fn content_hash_is_stable_wide_and_hex() {
        let h = content_hash("workload=X n=4 seed=42");
        assert_eq!(h.len(), 32);
        assert!(h.bytes().all(|b| b.is_ascii_hexdigit()));
        assert_eq!(h, content_hash("workload=X n=4 seed=42"), "deterministic");
        assert_ne!(h, content_hash("workload=X n=4 seed=43"), "keys separate");
        // The two lanes are independent: halves must not mirror.
        assert_ne!(&h[..16], &h[16..]);
    }

    #[test]
    fn save_load_roundtrip_and_counters() {
        let dir = crate::util::scratch_dir("store_rt").unwrap();
        let store = ResultStore::open(&dir).unwrap();
        assert!(store.load("fp-a", None).is_none(), "empty store misses");
        let path = store.save("fp-a", &result(0.8)).unwrap();
        assert!(path.starts_with(dir.join("objects")));
        let back = store.load("fp-a", None).expect("stored object loads");
        assert_eq!(back.summary.final_eval.metric, 0.8);
        assert_eq!(back.flavor, "D_ring");
        let stats = store.stats();
        assert_eq!(stats.objects, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.quarantined, 0);
        // A different fingerprint never aliases onto the stored object.
        assert!(store.load("fp-b", None).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_objects_are_quarantined_not_served() {
        let dir = crate::util::scratch_dir("store_corrupt").unwrap();
        let store = ResultStore::open(&dir).unwrap();
        let path = store.save("fp-q", &result(0.6)).unwrap();
        // Truncate the object mid-document: an un-fsynced legacy write
        // or bit rot would look exactly like this.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(store.load("fp-q", None).is_none(), "never served");
        assert!(!path.exists(), "removed from the serving path");
        assert!(path.with_extension("corrupt").exists(), "kept for forensics");
        let stats = store.stats();
        assert_eq!(stats.objects, 0);
        assert_eq!(stats.quarantined, 1);
        // The recomputed result stores cleanly over the quarantined slot.
        store.save("fp-q", &result(0.6)).unwrap();
        assert!(store.load("fp-q", None).is_some());
        assert_eq!(store.stats().objects, 1);
        assert_eq!(store.stats().quarantined, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_flat_layout_reads_and_migrates() {
        let dir = crate::util::scratch_dir("store_legacy").unwrap();
        let store = ResultStore::open(&dir).unwrap();
        let legacy = "cell_0000_4_D_ring.json";
        std::fs::write(
            dir.join(legacy),
            tagged_json("fp-old", &result(0.7)).to_string(),
        )
        .unwrap();
        assert!(
            !store.object_path("fp-old").exists(),
            "not yet content-addressed"
        );
        // Without the legacy name the store cannot see the flat file.
        assert!(store.load("fp-old", None).is_none());
        // With it, the result is served AND promoted into objects/.
        let back = store.load("fp-old", Some(legacy)).expect("legacy hit");
        assert_eq!(back.summary.final_eval.metric, 0.7);
        assert!(store.object_path("fp-old").exists(), "migrated");
        // Migrated object now serves without the legacy name.
        assert!(store.load("fp-old", None).is_some());
        // A stale legacy file (fingerprint drift) is a miss, not a hit.
        assert!(store.load("fp-new", Some(legacy)).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn identical_results_serialize_bitwise_identically() {
        // The BTreeMap-backed JSON writer is deterministic, which is
        // what lets the service promise bitwise-equal cached responses.
        let a = tagged_json("fp", &result(0.9)).to_string();
        let b = tagged_json("fp", &result(0.9)).to_string();
        assert_eq!(a, b);
    }
}
