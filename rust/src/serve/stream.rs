//! Per-job metric streaming: an append-only broadcast log of JSONL
//! event lines, fed by a streaming [`Observer`] attached to every cell
//! run.
//!
//! [`EventLog`] is a replay buffer, not a queue: every line is kept for
//! the job's lifetime, and any number of readers can attach at any time
//! — a stream request that arrives after the job finished replays the
//! full history and terminates, a reader attached mid-run blocks on
//! [`EventLog::wait_from`] until more lines (or the close marker)
//! arrive. That makes the HTTP chunked responses stateless: each
//! connection just carries a cursor.
//!
//! Line schema (`type` discriminates):
//!
//! ```text
//! {"type":"cell_start","cell":i,"scale":n,"strategy":"D_ring"}
//! {"type":"iteration","cell":i,"scale":n,"record":{…IterationRecord…}}
//! {"type":"epoch","cell":i,"scale":n,"epoch":e,"mean_gini":g|null,"label":"D_ring","seed":s}
//! {"type":"cell_retry","cell":i,"attempt":a,"error":"…"}
//! {"type":"cell_done","cell":i,"cached":bool,"summary":{…RunSummary…}}
//! {"type":"job_done","job":"j…","state":"done|failed|cancelled"}
//! ```
//!
//! `iteration`/`epoch` payloads reuse [`TrainEvent::to_json`] with the
//! cell coordinates spliced in, so stream lines parse back through
//! [`crate::metrics::IterationRecord::from_json`].

use crate::coordinator::observer::{ControlFlow, EpochInfo, Observer, TrainEvent};
use crate::error::Result;
use crate::metrics::IterationRecord;
use crate::util::json::Value;
use crate::util::matrix::ReplicaMatrix;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct LogState {
    lines: Vec<String>,
    closed: bool,
}

/// An append-only, close-once broadcast log of event lines.
pub struct EventLog {
    state: Mutex<LogState>,
    cv: Condvar,
}

impl Default for EventLog {
    fn default() -> Self {
        Self::new()
    }
}

impl EventLog {
    /// An empty, open log.
    pub fn new() -> Self {
        EventLog {
            state: Mutex::new(LogState { lines: Vec::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Append one line (ignored after [`EventLog::close`]) and wake
    /// blocked readers.
    pub fn push(&self, line: String) {
        let mut st = self.state.lock().expect("event log lock");
        if !st.closed {
            st.lines.push(line);
            self.cv.notify_all();
        }
    }

    /// Append a JSON value as one line.
    pub fn push_value(&self, v: &Value) {
        self.push(v.to_string());
    }

    /// Mark the log complete: readers drain the remaining lines and
    /// terminate. Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("event log lock");
        st.closed = true;
        self.cv.notify_all();
    }

    /// Whether the log is closed.
    pub fn is_closed(&self) -> bool {
        self.state.lock().expect("event log lock").closed
    }

    /// Lines appended so far.
    pub fn len(&self) -> usize {
        self.state.lock().expect("event log lock").lines.len()
    }

    /// Whether no lines were appended yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of `lines[from..]` plus the closed flag, non-blocking.
    pub fn read_from(&self, from: usize) -> (Vec<String>, bool) {
        let st = self.state.lock().expect("event log lock");
        (st.lines.get(from..).unwrap_or_default().to_vec(), st.closed)
    }

    /// Like [`EventLog::read_from`], but blocks up to `timeout` until
    /// there is at least one new line past `from` or the log closes.
    /// Returns the (possibly empty) new lines and the closed flag.
    pub fn wait_from(&self, from: usize, timeout: Duration) -> (Vec<String>, bool) {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().expect("event log lock");
        loop {
            if st.lines.len() > from || st.closed {
                return (st.lines.get(from..).unwrap_or_default().to_vec(), st.closed);
            }
            // Saturating: the deadline may already have passed (slow
            // wakeup, clock granularity) — never subtract Instants raw.
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return (Vec::new(), st.closed);
            };
            let (guard, res) = self
                .cv
                .wait_timeout(st, remaining)
                .expect("event log lock");
            st = guard;
            if res.timed_out() && st.lines.len() <= from && !st.closed {
                return (Vec::new(), st.closed);
            }
        }
    }
}

/// The streaming observer of one cell run: forwards every
/// iteration/epoch hook into the job's [`EventLog`] as a JSONL line
/// tagged with the cell coordinates. Completion is deliberately *not*
/// emitted here — the scheduler emits `cell_done` itself so cached
/// cells (which never run an observer) produce the same line.
pub struct StreamObserver {
    log: Arc<EventLog>,
    cell: usize,
    scale: usize,
}

impl StreamObserver {
    /// Stream cell `cell` (at `scale` workers) into `log`.
    pub fn new(log: Arc<EventLog>, cell: usize, scale: usize) -> Self {
        StreamObserver { log, cell, scale }
    }

    fn push_tagged(&self, event: &TrainEvent) {
        let mut v = event.to_json();
        if let Value::Obj(map) = &mut v {
            map.insert("cell".to_string(), Value::Num(self.cell as f64));
            map.insert("scale".to_string(), Value::Num(self.scale as f64));
        }
        self.log.push_value(&v);
    }
}

impl Observer for StreamObserver {
    fn on_iteration(
        &mut self,
        rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        self.push_tagged(&TrainEvent::Iteration(rec.clone()));
        Ok(ControlFlow::Continue)
    }

    fn on_epoch(&mut self, info: &EpochInfo<'_>) -> Result<ControlFlow> {
        self.push_tagged(&TrainEvent::from_epoch(info));
        Ok(ControlFlow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_replays_and_tails() {
        let log = EventLog::new();
        log.push("a".into());
        log.push("b".into());
        let (lines, closed) = log.read_from(0);
        assert_eq!(lines, vec!["a", "b"]);
        assert!(!closed);
        // Cursor past the end: nothing, still open.
        let (lines, closed) = log.read_from(2);
        assert!(lines.is_empty() && !closed);
        log.close();
        let (lines, closed) = log.read_from(1);
        assert_eq!(lines, vec!["b"]);
        assert!(closed);
        // Pushes after close are dropped.
        log.push("c".into());
        assert_eq!(log.len(), 2);
    }

    #[test]
    fn wait_from_blocks_until_data_or_close() {
        let log = Arc::new(EventLog::new());
        // Timeout path: nothing arrives.
        let (lines, closed) = log.wait_from(0, Duration::from_millis(20));
        assert!(lines.is_empty() && !closed);
        // Data path: a writer thread wakes the reader.
        let writer = {
            let log = Arc::clone(&log);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(10));
                log.push("x".into());
                log.close();
            })
        };
        let (lines, _) = log.wait_from(0, Duration::from_secs(10));
        assert_eq!(lines, vec!["x"]);
        writer.join().unwrap();
        // Close path: drained reader sees closed immediately.
        let (lines, closed) = log.wait_from(1, Duration::from_secs(10));
        assert!(lines.is_empty() && closed);
    }

    #[test]
    fn stream_observer_tags_lines_with_cell_coordinates() {
        use crate::metrics::VarianceReport;
        let log = Arc::new(EventLog::new());
        let mut obs = StreamObserver::new(Arc::clone(&log), 3, 8);
        let replicas = ReplicaMatrix::zeros(2, 4);
        let rec = IterationRecord {
            iteration: 5,
            epoch: 1,
            train_loss: 0.25,
            test_metric: None,
            variance: VarianceReport::of(&[]),
            per_tensor_gini: Vec::new(),
            graph_degree: 2,
            bytes_per_node: 16,
            lr: 0.1,
        };
        obs.on_iteration(&rec, &replicas).unwrap();
        obs.on_epoch(&EpochInfo {
            epoch: 1,
            mean_gini: None,
            replicas: &replicas,
            label: "D_ring",
            seed: 42,
        })
        .unwrap();
        let (lines, _) = log.read_from(0);
        assert_eq!(lines.len(), 2);
        let it = Value::parse(&lines[0]).unwrap();
        assert_eq!(it.str_field("type").unwrap(), "iteration");
        assert_eq!(it.usize_field("cell").unwrap(), 3);
        assert_eq!(it.usize_field("scale").unwrap(), 8);
        let back = IterationRecord::from_json(it.get("record").unwrap()).unwrap();
        assert_eq!(back.iteration, 5);
        assert_eq!(back.train_loss, 0.25);
        let ep = Value::parse(&lines[1]).unwrap();
        assert_eq!(ep.str_field("type").unwrap(), "epoch");
        assert_eq!(ep.get("mean_gini"), Some(&Value::Null));
        assert_eq!(ep.str_field("label").unwrap(), "D_ring");
    }
}
