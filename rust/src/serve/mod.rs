//! The experiment service: dbench as a long-lived, multi-tenant server
//! (ROADMAP direction 5) — pure std, like everything else in the crate.
//!
//! Four layers, composed left to right:
//!
//! * [`http`] — a minimal HTTP/1.1 front end over
//!   `std::net::TcpListener`: submit a spec (TOML or JSON), query and
//!   cancel jobs, fetch results, and stream per-epoch/per-iteration
//!   metrics as chunked JSONL. Also ships the matching client half
//!   behind `dbench submit/status/results/stream`.
//! * [`scheduler`] — one shared bounded worker pool over the existing
//!   cell machinery, scheduling cells across jobs by integer priority
//!   and deficit-based fair share, with cell-boundary cancellation.
//! * [`store`] — the content-addressed [`ResultStore`] of finished
//!   [`crate::dbench::CellResult`]s, keyed by the cell
//!   [`crate::dbench::fingerprint`]; shared byte-for-byte with the CLI
//!   `--resume-dir` cache (legacy flat-layout files are read and
//!   migrated in place).
//! * [`stream`] — the per-job [`EventLog`] replay buffer and the
//!   [`StreamObserver`] that forwards training events into it.
//!
//! Graceful shutdown drains in-flight cells into the store — cell
//! granularity is the checkpoint, so a restarted server re-runs
//! nothing that finished.

pub mod http;
pub mod scheduler;
pub mod store;
pub mod stream;

pub use http::{http_request, http_stream_lines, start, ServeConfig, Server};
pub use scheduler::{CancelStop, Job, JobStatus, Scheduler};
pub use store::{content_hash, ResultStore, StoreStats};
pub use stream::{EventLog, StreamObserver};
