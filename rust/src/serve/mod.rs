//! The experiment service: dbench as a long-lived, multi-tenant server
//! (ROADMAP direction 5) — pure std, like everything else in the crate.
//!
//! Five layers, composed left to right:
//!
//! * [`http`] — a minimal HTTP/1.1 front end over
//!   `std::net::TcpListener`: submit a spec (TOML or JSON), query and
//!   cancel jobs, fetch results, and stream per-epoch/per-iteration
//!   metrics as chunked JSONL. Bounded (connection cap with 503
//!   shedding, 408 on stalled uploads) and shipped with a retrying
//!   client half behind `dbench submit/status/results/stream`.
//! * [`scheduler`] — one shared bounded worker pool over the existing
//!   cell machinery, scheduling cells across jobs by integer priority
//!   and deficit-based fair share, with cell-boundary cancellation,
//!   panic containment, deterministic-backoff retries and a watchdog
//!   that turns per-cell deadlines into cooperative stops.
//! * [`journal`] — the fsynced write-ahead log of submissions and
//!   terminal transitions that makes the queue durable: a restarted
//!   scheduler replays it and re-enqueues every non-terminal job under
//!   its original id.
//! * [`store`] — the content-addressed [`ResultStore`] of finished
//!   [`crate::dbench::CellResult`]s, keyed by the cell
//!   [`crate::dbench::fingerprint`]; crash-atomic writes, corrupt
//!   objects quarantined (`*.corrupt`), shared byte-for-byte with the
//!   CLI `--resume-dir` cache (legacy flat-layout files are read and
//!   migrated in place).
//! * [`stream`] — the per-job [`EventLog`] replay buffer and the
//!   [`StreamObserver`] that forwards training events into it.
//!
//! Graceful shutdown drains in-flight cells into the store — cell
//! granularity is the checkpoint, so a restarted server re-runs
//! nothing that finished. An abrupt stop (crash, `kill -9`,
//! `shutdown(drain=false)`) loses at most the in-flight cells: the
//! journal re-enqueues the jobs, the store serves the finished cells,
//! and recovery converges to byte-identical results.

pub mod http;
pub mod journal;
pub mod scheduler;
pub mod store;
pub mod stream;

pub use http::{
    http_request, http_request_with, http_stream_lines, http_stream_lines_with, start,
    ClientConfig, ServeConfig, Server,
};
pub use journal::Journal;
pub use scheduler::{
    CancelStop, Job, JobStatus, Scheduler, SchedulerConfig, SubmitOptions,
};
pub use store::{content_hash, ResultStore, StoreStats};
pub use stream::{EventLog, StreamObserver};
