//! The multi-tenant cell scheduler: every submitted job expands to
//! [`CellPlan`]s up front (the existing enumeration machinery), and one
//! shared bounded worker pool drains cells across **all** jobs under a
//! two-level policy:
//!
//! 1. **Integer priority** — a runnable cell of a higher-priority job
//!    always dispatches before any lower-priority cell. A high-priority
//!    job submitted mid-sweep therefore preempts the *remaining* cells
//!    of a low-priority sweep (in-flight cells are never aborted by
//!    priority — cells are the preemption granularity).
//! 2. **Deficit fair-share within a priority band** — each job carries
//!    a weight (default 1); dispatching one cell costs that job
//!    `1/weight` of virtual time, and the runnable job with the lowest
//!    virtual time goes next (ties broken by submission order). A
//!    1024-cell sweep at weight 1 and an interactive job at weight 1
//!    therefore alternate cells instead of the sweep starving the
//!    newcomer. The accounting is deterministic — with one worker the
//!    interleaving is an exact function of the submission sequence,
//!    which the integration tests pin.
//!
//! Every dispatch consults the shared [`ResultStore`] first: a
//! fingerprint hit returns the stored result without running anything
//! (and still emits a `cell_done {cached:true}` stream event). Completed
//! cells are persisted back, so an interrupted job resumes at cell
//! granularity — the store *is* the checkpoint.
//!
//! Cancellation is cooperative and bounded by one cell: the scheduler
//! stops dispatching a cancelled job immediately, and the in-flight
//! cell's [`CancelStop`] observer ends its run at the next iteration
//! boundary; a cancelled cell's partial result is **discarded**, never
//! stored (cache-poisoning guard).
//!
//! ## Durability and self-healing
//!
//! With [`SchedulerConfig::journal`] on, spec-backed submissions are
//! appended to the [`Journal`] under the store root before the submit
//! returns, terminal transitions are journaled too, and
//! [`Scheduler::start_cfg`] replays the log: a restarted server
//! re-enqueues every non-terminal job under its **original id**,
//! serves the cells that finished from the store, and re-runs the
//! rest — recovery converges to byte-identical results. The workers
//! self-heal: a panicking cell fails *its* job with the panic message
//! (the worker thread survives via `catch_unwind`, so pool capacity
//! never shrinks), transient cell errors retry up to `retries` times
//! with deterministic seeded jittered backoff, and a per-cell
//! `deadline_s` turns a wedged cell into a cooperative stop via the
//! watchdog thread.

use super::journal::Journal;
use super::store::{content_hash, ResultStore};
use super::stream::{EventLog, StreamObserver};
use crate::coordinator::observer::{ControlFlow, EpochInfo, Observer};
use crate::dbench::{CellPlan, CellResult, ExperimentSpec, SessionPlan};
use crate::error::{AdaError, Result};
use crate::metrics::IterationRecord;
use crate::util::json::Value;
use crate::util::matrix::ReplicaMatrix;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Stop an in-flight cell run at the next iteration/epoch boundary once
/// the shared flag flips — the cancellation, non-drain shutdown and
/// deadline paths of the service. Relies on the session's early-stop
/// contract: the run still evaluates and returns, and the scheduler
/// then discards (or deadline-fails) the truncated result.
pub struct CancelStop {
    flag: Arc<AtomicBool>,
}

impl CancelStop {
    /// Stop when `flag` becomes true.
    pub fn new(flag: Arc<AtomicBool>) -> Self {
        CancelStop { flag }
    }

    fn verdict(&self) -> ControlFlow {
        if self.flag.load(Ordering::Relaxed) {
            ControlFlow::Stop
        } else {
            ControlFlow::Continue
        }
    }
}

impl Observer for CancelStop {
    fn on_iteration(
        &mut self,
        _rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        Ok(self.verdict())
    }

    fn on_epoch(&mut self, _info: &EpochInfo<'_>) -> Result<ControlFlow> {
        Ok(self.verdict())
    }
}

/// Executor-level knobs of one [`Scheduler`] (the `dbench serve`
/// flags). `retries` and `deadline_s` are per-job defaults that
/// [`SubmitOptions`] can override per submission.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Concurrent cell workers (min 1).
    pub workers: usize,
    /// Start with the dispatch gate closed ([`Scheduler::resume`]
    /// opens it).
    pub paused: bool,
    /// Journal spec-backed submissions under `<store>/journal/` and
    /// replay them on start.
    pub journal: bool,
    /// Default transient-failure retries per cell.
    pub retries: usize,
    /// Default per-cell wall-clock deadline in seconds (0 = none).
    pub deadline_s: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            workers: 1,
            paused: false,
            journal: false,
            retries: 0,
            deadline_s: 0.0,
        }
    }
}

/// Per-submission options ([`Scheduler::submit_spec`] /
/// [`Scheduler::submit_plan`]).
#[derive(Debug, Clone)]
pub struct SubmitOptions {
    /// Scheduling priority (higher dispatches first).
    pub priority: i64,
    /// Fair-share weight within a priority band (> 0).
    pub weight: f64,
    /// Replicate every cell this many times with derived seeds
    /// (≤ 1 = no replication).
    pub seeds: usize,
    /// Return the existing job instead of a `-N`-suffixed duplicate
    /// when an identical submission is already known — the retry-safe
    /// `POST /jobs?idempotent=true` mode.
    pub idempotent: bool,
    /// Per-job transient-failure retries per cell (overrides the
    /// scheduler default).
    pub retries: Option<usize>,
    /// Per-job cell deadline in seconds (overrides the scheduler
    /// default; 0 disables).
    pub deadline_s: Option<f64>,
}

impl Default for SubmitOptions {
    fn default() -> Self {
        SubmitOptions {
            priority: 0,
            weight: 1.0,
            seeds: 0,
            idempotent: false,
            retries: None,
            deadline_s: None,
        }
    }
}

/// One submitted experiment: an expanded [`SessionPlan`] plus
/// scheduling identity and the job's event stream. Results accumulate
/// per cell slot as cells finish (in any order).
pub struct Job {
    /// Deterministic job id (`j` + 12 hex of the content hash over the
    /// cell fingerprints and scheduling parameters, `-N`-suffixed when
    /// the same submission repeats).
    pub id: String,
    /// Spec name (display only).
    pub name: String,
    /// Scheduling priority (higher dispatches first).
    pub priority: i64,
    /// Fair-share weight within a priority band (> 0).
    pub weight: f64,
    /// Submission sequence number (final tiebreak).
    pub seq: usize,
    /// Transient-failure retries per cell.
    pub retries: usize,
    /// Per-cell wall-clock deadline in seconds (0 = none).
    pub deadline_s: f64,
    /// The expanded plan. `resume_dir` stays `None` here — the
    /// scheduler owns all store traffic so cancelled runs can be
    /// discarded before they ever touch disk.
    pub plan: SessionPlan,
    /// The job's JSONL event stream (closed when the job finishes).
    pub events: Arc<EventLog>,
    cancelled: Arc<AtomicBool>,
    results: Mutex<Vec<Option<CellResult>>>,
}

impl Job {
    /// Whether the job was cancelled.
    pub fn cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// The job's results document: a `cells` array in enumeration order
    /// (`null` for cells not finished) plus a `complete` flag.
    /// Deliberately excludes the job id and any timing, so two jobs
    /// over identical specs serialize to **bitwise-identical** bytes
    /// once complete — the cache-hit contract the integration tests
    /// compare byte-for-byte.
    pub fn results_json(&self) -> Value {
        let results = self.results.lock().expect("job results lock");
        let complete = !results.is_empty() && results.iter().all(Option::is_some);
        Value::obj(vec![
            ("complete", Value::Bool(complete)),
            (
                "cells",
                Value::Arr(
                    results
                        .iter()
                        .map(|r| r.as_ref().map(CellResult::to_json).unwrap_or(Value::Null))
                        .collect(),
                ),
            ),
        ])
    }
}

/// A point-in-time scheduling snapshot of one job.
#[derive(Debug, Clone)]
pub struct JobStatus {
    /// Job id.
    pub id: String,
    /// Spec name.
    pub name: String,
    /// `queued` | `running` | `done` | `cancelled` | `failed`.
    pub state: String,
    /// Scheduling priority.
    pub priority: i64,
    /// Fair-share weight.
    pub weight: f64,
    /// Total cells in the plan.
    pub total: usize,
    /// Cells not yet dispatched.
    pub pending: usize,
    /// Cells currently executing.
    pub running: usize,
    /// Cells finished (including cache hits).
    pub done: usize,
    /// Finished cells that were served from the store.
    pub cached: usize,
    /// First cell error, if the job failed.
    pub error: Option<String>,
}

impl JobStatus {
    /// JSON encoding (the `/jobs` endpoints).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("id", Value::Str(self.id.clone())),
            ("name", Value::Str(self.name.clone())),
            ("state", Value::Str(self.state.clone())),
            ("priority", Value::Num(self.priority as f64)),
            ("weight", Value::Num(self.weight)),
            ("total", Value::Num(self.total as f64)),
            ("pending", Value::Num(self.pending as f64)),
            ("running", Value::Num(self.running as f64)),
            ("done", Value::Num(self.done as f64)),
            ("cached", Value::Num(self.cached as f64)),
            (
                "error",
                match &self.error {
                    Some(e) => Value::Str(e.clone()),
                    None => Value::Null,
                },
            ),
        ])
    }
}

/// Per-job scheduling state. Lives entirely under the scheduler's one
/// inner lock; the only other lock in the subsystem (`Job::results`) is
/// never held at the same time, so lock ordering is trivial.
struct Entry {
    job: Arc<Job>,
    pending: VecDeque<usize>,
    dispatched: usize,
    running: usize,
    done: usize,
    cached: usize,
    error: Option<String>,
    finished: bool,
}

impl Entry {
    fn runnable(&self) -> bool {
        !self.pending.is_empty() && self.error.is_none() && !self.job.cancelled()
    }

    /// Virtual time consumed: dispatches weighted by `1/weight`.
    fn vtime(&self) -> f64 {
        self.dispatched as f64 / self.job.weight
    }

    fn state(&self) -> &'static str {
        if self.error.is_some() {
            "failed"
        } else if self.job.cancelled() {
            "cancelled"
        } else if self.pending.is_empty() && self.running == 0 {
            "done"
        } else if self.running > 0 || self.done > 0 {
            "running"
        } else {
            "queued"
        }
    }

    fn status(&self) -> JobStatus {
        JobStatus {
            id: self.job.id.clone(),
            name: self.job.name.clone(),
            state: self.state().to_string(),
            priority: self.job.priority,
            weight: self.job.weight,
            total: self.job.plan.cells.len(),
            pending: self.pending.len(),
            running: self.running,
            done: self.done,
            cached: self.cached,
            error: self.error.clone(),
        }
    }
}

struct Inner {
    entries: BTreeMap<String, Entry>,
    order: Vec<String>,
    next_seq: usize,
    paused: bool,
    stopping: bool,
    dispatch_log: Vec<(String, usize)>,
}

impl Inner {
    /// The scheduling rule: among runnable jobs pick max priority, then
    /// min virtual time, then min submission sequence. Cells within a
    /// job always dispatch in enumeration order.
    fn pick(&self) -> Option<String> {
        let mut best: Option<&Entry> = None;
        for e in self.entries.values() {
            if !e.runnable() {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => {
                    if e.job.priority != b.job.priority {
                        e.job.priority > b.job.priority
                    } else if e.vtime() != b.vtime() {
                        e.vtime() < b.vtime()
                    } else {
                        e.job.seq < b.job.seq
                    }
                }
            };
            if better {
                best = Some(e);
            }
        }
        best.map(|e| e.job.id.clone())
    }
}

enum Outcome {
    Done(CellResult, bool),
    Discarded,
    Failed(String),
}

/// The watchdog's registry of in-flight deadlines.
struct WatchState {
    entries: Vec<(u64, Instant, Arc<AtomicBool>)>,
    next_token: u64,
    stop: bool,
}

/// The shared bounded executor over all submitted jobs. Construct with
/// [`Scheduler::start`] / [`Scheduler::start_cfg`]; workers live until
/// [`Scheduler::shutdown`].
pub struct Scheduler {
    store: Arc<ResultStore>,
    workers: usize,
    defaults: SchedulerConfig,
    journal: Option<Journal>,
    /// Non-drain shutdown: in-flight cells stop at the next iteration
    /// boundary and are discarded, but jobs keep their non-terminal
    /// journal state so a restart replays them.
    abort: Arc<AtomicBool>,
    inner: Mutex<Inner>,
    cv: Condvar,
    done_cv: Condvar,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    watch: Mutex<WatchState>,
    watch_cv: Condvar,
    watch_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Scheduler {
    /// Spawn `workers` (min 1) cell workers draining into `store`, with
    /// journaling off — the programmatic/test entry point. `paused`
    /// starts the dispatch gate closed.
    pub fn start(store: Arc<ResultStore>, workers: usize, paused: bool) -> Arc<Scheduler> {
        Self::start_cfg(
            store,
            SchedulerConfig { workers, paused, ..SchedulerConfig::default() },
        )
        .expect("scheduler start without a journal cannot fail")
    }

    /// Spawn the executor per `cfg`. With `cfg.journal` on, the journal
    /// under `<store>/journal/` is opened (created if absent), replayed
    /// — every non-terminal spec submission re-enters the queue under
    /// its original id, in original submission order — and compacted
    /// down to the live set before any worker starts.
    pub fn start_cfg(store: Arc<ResultStore>, cfg: SchedulerConfig) -> Result<Arc<Scheduler>> {
        let journal = if cfg.journal {
            Some(Journal::open(&store.root().join("journal"))?)
        } else {
            None
        };
        let sched = Arc::new(Scheduler {
            store,
            workers: cfg.workers.max(1),
            defaults: cfg.clone(),
            journal,
            abort: Arc::new(AtomicBool::new(false)),
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                order: Vec::new(),
                next_seq: 0,
                paused: cfg.paused,
                stopping: false,
                dispatch_log: Vec::new(),
            }),
            cv: Condvar::new(),
            done_cv: Condvar::new(),
            handles: Mutex::new(Vec::new()),
            watch: Mutex::new(WatchState {
                entries: Vec::new(),
                next_token: 0,
                stop: false,
            }),
            watch_cv: Condvar::new(),
            watch_handle: Mutex::new(None),
        });
        // Recovery happens before any worker exists, so replayed jobs
        // queue atomically with respect to new submissions.
        if sched.journal.is_some() {
            sched.replay_journal();
        }
        {
            let s = Arc::clone(&sched);
            *sched.watch_handle.lock().expect("watchdog handle lock") =
                Some(std::thread::spawn(move || s.watchdog_loop()));
        }
        let mut handles = sched.handles.lock().expect("scheduler handles lock");
        for _ in 0..sched.workers {
            let s = Arc::clone(&sched);
            handles.push(std::thread::spawn(move || s.worker_loop()));
        }
        drop(handles);
        Ok(sched)
    }

    /// Submit an expanded plan with default options. Returns the job
    /// handle with its deterministic id assigned.
    pub fn submit(
        &self,
        name: String,
        priority: i64,
        weight: f64,
        plan: SessionPlan,
    ) -> Result<Arc<Job>> {
        self.submit_plan(
            name,
            plan,
            &SubmitOptions { priority, weight, ..SubmitOptions::default() },
        )
    }

    /// Submit a programmatic plan. Not journaled — only spec-backed
    /// submissions ([`Scheduler::submit_spec`]) can be replayed, since
    /// replay re-parses the spec text.
    pub fn submit_plan(
        &self,
        name: String,
        plan: SessionPlan,
        opts: &SubmitOptions,
    ) -> Result<Arc<Job>> {
        self.submit_inner(name, plan, opts, None, None)
    }

    /// Parse, expand and submit a spec (TOML or JSON — the `POST /jobs`
    /// body). The verbatim text is journaled (when journaling is on) so
    /// a restarted scheduler replays the submission exactly.
    pub fn submit_spec(&self, text: &str, opts: &SubmitOptions) -> Result<Arc<Job>> {
        let spec = ExperimentSpec::from_text(text)?;
        let mut plan = SessionPlan::from_spec(&spec);
        plan.expand_seeds(opts.seeds);
        self.submit_inner(spec.name.clone(), plan, opts, Some(text), None)
    }

    fn submit_inner(
        &self,
        name: String,
        mut plan: SessionPlan,
        opts: &SubmitOptions,
        spec_text: Option<&str>,
        pinned_id: Option<&str>,
    ) -> Result<Arc<Job>> {
        if plan.cells.is_empty() {
            return Err(AdaError::Config("spec expands to zero cells".into()));
        }
        if !(opts.weight > 0.0 && opts.weight.is_finite()) {
            return Err(AdaError::Config(format!(
                "job weight must be finite and > 0, got {}",
                opts.weight
            )));
        }
        // The scheduler owns all store traffic (see `Job::plan`).
        plan.resume_dir = None;
        let total = plan.cells.len();
        let base = match pinned_id {
            Some(id) => id.to_string(),
            None => {
                let mut material =
                    format!("priority={} weight={}", opts.priority, opts.weight);
                for cell in &plan.cells {
                    material.push(' ');
                    material.push_str(&plan.cell_fingerprint(cell));
                }
                format!("j{}", &content_hash(&material)[..12])
            }
        };
        let mut inner = self.inner.lock().expect("scheduler lock");
        if inner.stopping {
            return Err(AdaError::Runtime("scheduler is shutting down".into()));
        }
        if pinned_id.is_some() && inner.entries.contains_key(&base) {
            return Err(AdaError::Runtime(format!("job {base} already exists")));
        }
        if opts.idempotent {
            if let Some(e) = inner.entries.get(&base) {
                return Ok(Arc::clone(&e.job));
            }
        }
        let mut id = base.clone();
        let mut n = 1usize;
        while inner.entries.contains_key(&id) {
            n += 1;
            id = format!("{base}-{n}");
        }
        let job = Arc::new(Job {
            id: id.clone(),
            name,
            priority: opts.priority,
            weight: opts.weight,
            seq: inner.next_seq,
            retries: opts.retries.unwrap_or(self.defaults.retries),
            deadline_s: opts.deadline_s.unwrap_or(self.defaults.deadline_s),
            plan,
            events: Arc::new(EventLog::new()),
            cancelled: Arc::new(AtomicBool::new(false)),
            results: Mutex::new((0..total).map(|_| None).collect()),
        });
        // Durability before visibility: the submit record is fsynced
        // while the inner lock is held (journal order = seq order, so
        // replay preserves the fair-share tiebreak), and a failed
        // append fails the submission instead of admitting a job that
        // would vanish on restart. Replayed jobs (pinned id) skip the
        // append — compaction already rewrote their records.
        if pinned_id.is_none() {
            if let (Some(journal), Some(text)) = (&self.journal, spec_text) {
                journal.append(&submit_record(&job, opts, text))?;
            }
        }
        inner.next_seq += 1;
        inner.entries.insert(
            id.clone(),
            Entry {
                job: Arc::clone(&job),
                pending: (0..total).collect(),
                dispatched: 0,
                running: 0,
                done: 0,
                cached: 0,
                error: None,
                finished: false,
            },
        );
        inner.order.push(id);
        drop(inner);
        self.cv.notify_all();
        Ok(job)
    }

    /// Re-enqueue every journaled non-terminal submission, then compact
    /// the journal down to exactly those records. Unparseable records
    /// are dropped (and compacted away) rather than wedging recovery.
    fn replay_journal(&self) {
        let journal = self.journal.as_ref().expect("journal enabled");
        let records = journal.replay();
        let mut terminal: BTreeSet<String> = BTreeSet::new();
        for r in &records {
            if matches!(r.str_field("type"), Ok("cancel") | Ok("done")) {
                if let Ok(id) = r.str_field("id") {
                    terminal.insert(id.to_string());
                }
            }
        }
        let mut live = Vec::new();
        for r in &records {
            if !matches!(r.str_field("type"), Ok("submit")) {
                continue;
            }
            let (Ok(id), Ok(text)) = (r.str_field("id"), r.str_field("spec")) else {
                continue;
            };
            if terminal.contains(id) {
                continue;
            }
            let Ok(spec) = ExperimentSpec::from_text(text) else {
                continue;
            };
            let mut plan = SessionPlan::from_spec(&spec);
            let seeds = r.usize_field("seeds").unwrap_or(0);
            plan.expand_seeds(seeds);
            let opts = SubmitOptions {
                priority: r.num_field("priority").unwrap_or(0.0) as i64,
                weight: r.num_field("weight").unwrap_or(1.0),
                seeds,
                idempotent: false,
                retries: r.num_field("retries").ok().map(|n| n.max(0.0) as usize),
                deadline_s: r.num_field("deadline_s").ok(),
            };
            live.push((id.to_string(), spec.name.clone(), plan, opts, r.clone()));
        }
        // Compact first: a crash between the rewrite and the (lockstep,
        // in-memory) re-submissions below still leaves every live
        // record on disk for the next restart.
        let compacted: Vec<Value> = live.iter().map(|(_, _, _, _, r)| r.clone()).collect();
        let _ = journal.rewrite(&compacted);
        for (id, name, plan, opts, _) in live {
            let _ = self.submit_inner(name, plan, &opts, None, Some(&id));
        }
    }

    /// Close the dispatch gate: in-flight cells finish, nothing new
    /// dispatches until [`Scheduler::resume`].
    pub fn pause(&self) {
        self.inner.lock().expect("scheduler lock").paused = true;
        self.cv.notify_all();
    }

    /// Reopen the dispatch gate.
    pub fn resume(&self) {
        self.inner.lock().expect("scheduler lock").paused = false;
        self.cv.notify_all();
    }

    /// Whether the dispatch gate is closed.
    pub fn paused(&self) -> bool {
        self.inner.lock().expect("scheduler lock").paused
    }

    /// Cancel a job: no further cells dispatch, and the in-flight cell
    /// (if any) stops at its next iteration boundary and is discarded.
    /// Returns the post-cancel status, or `None` for an unknown id.
    pub fn cancel(&self, id: &str) -> Option<JobStatus> {
        let mut inner = self.inner.lock().expect("scheduler lock");
        let entry = inner.entries.get_mut(id)?;
        entry.job.cancelled.store(true, Ordering::SeqCst);
        let finalize = entry.running == 0 && !entry.finished;
        if finalize {
            entry.finished = true;
        }
        let events = Arc::clone(&entry.job.events);
        let status = entry.status();
        drop(inner);
        // Terminal for replay purposes: a restart must not revive a
        // cancelled job.
        if let Some(journal) = &self.journal {
            let _ = journal.append(&Value::obj(vec![
                ("type", Value::Str("cancel".into())),
                ("id", Value::Str(id.to_string())),
            ]));
        }
        if finalize {
            events.push_value(&job_done_event(id, "cancelled"));
            events.close();
        }
        self.cv.notify_all();
        self.done_cv.notify_all();
        Some(status)
    }

    /// Status of one job.
    pub fn status(&self, id: &str) -> Option<JobStatus> {
        let inner = self.inner.lock().expect("scheduler lock");
        inner.entries.get(id).map(Entry::status)
    }

    /// All jobs in submission order.
    pub fn list(&self) -> Vec<JobStatus> {
        let inner = self.inner.lock().expect("scheduler lock");
        inner
            .order
            .iter()
            .filter_map(|id| inner.entries.get(id))
            .map(Entry::status)
            .collect()
    }

    /// The job handle for `id`.
    pub fn job(&self, id: &str) -> Option<Arc<Job>> {
        let inner = self.inner.lock().expect("scheduler lock");
        inner.entries.get(id).map(|e| Arc::clone(&e.job))
    }

    /// The full dispatch history as `(job id, cell index)` pairs, in
    /// dispatch order — the observable the fair-share tests assert on.
    pub fn dispatch_log(&self) -> Vec<(String, usize)> {
        self.inner.lock().expect("scheduler lock").dispatch_log.clone()
    }

    /// Block until `id` reaches a terminal state (or `timeout`
    /// elapses). Returns the final status, `None` on unknown id or
    /// timeout.
    pub fn wait(&self, id: &str, timeout: Duration) -> Option<JobStatus> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().expect("scheduler lock");
        loop {
            let status = inner.entries.get(id).map(Entry::status)?;
            if matches!(status.state.as_str(), "done" | "failed" | "cancelled")
                && status.running == 0
            {
                return Some(status);
            }
            // Saturating wait: the deadline may already have passed.
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return None;
            };
            let (guard, _) = self
                .done_cv
                .wait_timeout(inner, remaining)
                .expect("scheduler lock");
            inner = guard;
        }
    }

    /// Stop the executor. `drain = true` (graceful) lets in-flight
    /// cells run to completion and persist to the store — cell
    /// granularity *is* the checkpoint; `drain = false` sets the
    /// scheduler-wide abort flag so in-flight cells stop at their next
    /// iteration boundary and are discarded, while the jobs stay
    /// non-terminal in the journal — the abrupt-stop path a restarted
    /// server replays. Either way no new cells dispatch, workers and
    /// the watchdog are joined, and every event log is closed so
    /// attached streams terminate.
    pub fn shutdown(&self, drain: bool) {
        {
            let mut inner = self.inner.lock().expect("scheduler lock");
            inner.stopping = true;
            inner.paused = false;
            if !drain {
                self.abort.store(true, Ordering::SeqCst);
            }
        }
        self.cv.notify_all();
        let handles: Vec<_> =
            std::mem::take(&mut *self.handles.lock().expect("scheduler handles lock"));
        for h in handles {
            let _ = h.join();
        }
        {
            let mut watch = self.watch.lock().expect("watchdog lock");
            watch.stop = true;
        }
        self.watch_cv.notify_all();
        if let Some(h) = self.watch_handle.lock().expect("watchdog handle lock").take() {
            let _ = h.join();
        }
        let inner = self.inner.lock().expect("scheduler lock");
        for e in inner.entries.values() {
            e.job.events.close();
        }
        drop(inner);
        self.done_cv.notify_all();
    }

    fn aborting(&self) -> bool {
        self.abort.load(Ordering::SeqCst)
    }

    fn worker_loop(&self) {
        while let Some((job, idx)) = self.next_cell() {
            self.run_cell(&job, idx);
        }
    }

    /// Block for the next dispatch (respecting pause/priority/fair
    /// share); `None` once the scheduler is stopping.
    fn next_cell(&self) -> Option<(Arc<Job>, usize)> {
        let mut inner = self.inner.lock().expect("scheduler lock");
        loop {
            if inner.stopping {
                return None;
            }
            if !inner.paused {
                if let Some(id) = inner.pick() {
                    let entry = inner.entries.get_mut(&id).expect("picked entry");
                    let idx = entry.pending.pop_front().expect("runnable entry");
                    entry.dispatched += 1;
                    entry.running += 1;
                    let job = Arc::clone(&entry.job);
                    inner.dispatch_log.push((id, idx));
                    return Some((job, idx));
                }
            }
            inner = self.cv.wait(inner).expect("scheduler lock");
        }
    }

    // ---- the watchdog -------------------------------------------------

    /// Register a cell deadline; the watchdog flips `flag` when it
    /// expires. Returns the token for [`Scheduler::watch_deregister`].
    fn watch_register(&self, deadline: Instant, flag: Arc<AtomicBool>) -> u64 {
        let mut watch = self.watch.lock().expect("watchdog lock");
        let token = watch.next_token;
        watch.next_token += 1;
        watch.entries.push((token, deadline, flag));
        drop(watch);
        self.watch_cv.notify_all();
        token
    }

    fn watch_deregister(&self, token: u64) {
        let mut watch = self.watch.lock().expect("watchdog lock");
        watch.entries.retain(|(t, _, _)| *t != token);
    }

    /// One parked thread that turns wall-clock deadlines into
    /// cooperative stops: it sleeps until the earliest registered
    /// deadline (or a registry change), flips expired flags, and lets
    /// the cell's `CancelStop`-style observer end the run at the next
    /// iteration boundary.
    fn watchdog_loop(&self) {
        let mut watch = self.watch.lock().expect("watchdog lock");
        loop {
            if watch.stop {
                return;
            }
            let now = Instant::now();
            for (_, deadline, flag) in &watch.entries {
                if now >= *deadline {
                    flag.store(true, Ordering::SeqCst);
                }
            }
            watch.entries.retain(|(_, _, flag)| !flag.load(Ordering::SeqCst));
            let next = watch
                .entries
                .iter()
                .map(|(_, deadline, _)| {
                    deadline.checked_duration_since(now).unwrap_or(Duration::ZERO)
                })
                .min();
            watch = match next {
                Some(wait) => {
                    let (guard, _) = self
                        .watch_cv
                        .wait_timeout(watch, wait.max(Duration::from_millis(5)))
                        .expect("watchdog lock");
                    guard
                }
                None => self.watch_cv.wait(watch).expect("watchdog lock"),
            };
        }
    }

    // ---- cell execution -----------------------------------------------

    /// Run one attempt loop for a cell: panic containment, deadline
    /// enforcement, and deterministic-backoff retries for transient
    /// errors.
    fn execute_cell(
        &self,
        job: &Arc<Job>,
        idx: usize,
        cell: &CellPlan,
        fingerprint: &str,
    ) -> Outcome {
        let mut attempt = 0usize;
        loop {
            let deadline_flag = Arc::new(AtomicBool::new(false));
            let token = (job.deadline_s > 0.0).then(|| {
                self.watch_register(
                    Instant::now() + Duration::from_secs_f64(job.deadline_s),
                    Arc::clone(&deadline_flag),
                )
            });
            let observers: Vec<Box<dyn Observer>> = vec![
                Box::new(StreamObserver::new(Arc::clone(&job.events), idx, cell.scale)),
                Box::new(CancelStop::new(Arc::clone(&job.cancelled))),
                Box::new(CancelStop::new(Arc::clone(&self.abort))),
                Box::new(CancelStop::new(Arc::clone(&deadline_flag))),
            ];
            // A panic anywhere inside the cell fails *this job* and
            // leaves the worker thread alive — pool capacity never
            // shrinks to a poisoned model or strategy.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                job.plan.run_cell_plan_with(cell, observers)
            }));
            if let Some(token) = token {
                self.watch_deregister(token);
            }
            let timed_out = deadline_flag.load(Ordering::SeqCst);
            return match run {
                Err(payload) => Outcome::Failed(format!(
                    "cell {idx} panicked: {}",
                    panic_message(payload.as_ref())
                )),
                // Deadline beats everything but cancellation-by-panic:
                // even an `Ok` result of a timed-out run is a truncated
                // run, never a storable result.
                Ok(_) if timed_out => Outcome::Failed(format!(
                    "cell {idx} exceeded its deadline of {}s",
                    job.deadline_s
                )),
                Ok(Ok(_)) if job.cancelled() || self.aborting() => Outcome::Discarded,
                Ok(Ok(result)) => {
                    let _ = self.store.save(fingerprint, &result);
                    Outcome::Done(result, false)
                }
                Ok(Err(e)) => {
                    if attempt >= job.retries || job.cancelled() || self.aborting() {
                        Outcome::Failed(e.to_string())
                    } else {
                        attempt += 1;
                        job.events.push_value(&Value::obj(vec![
                            ("type", Value::Str("cell_retry".into())),
                            ("cell", Value::Num(idx as f64)),
                            ("attempt", Value::Num(attempt as f64)),
                            ("error", Value::Str(e.to_string())),
                        ]));
                        std::thread::sleep(backoff_delay(&job.id, idx, attempt));
                        continue;
                    }
                }
            };
        }
    }

    fn run_cell(&self, job: &Arc<Job>, idx: usize) {
        let mut cell = job.plan.cells[idx].clone();
        // Same discipline as `SessionPlan::run`: concurrent cells force
        // auto-threaded configs to one thread so cell-level parallelism
        // and the intra-cell pool don't oversubscribe the cores
        // (bit-identical either way, so the cache key ignores it).
        if self.workers > 1 && cell.config.threads == 0 {
            cell.config.threads = 1;
        }
        let fingerprint = job.plan.cell_fingerprint(&cell);
        job.events.push_value(&Value::obj(vec![
            ("type", Value::Str("cell_start".into())),
            ("cell", Value::Num(idx as f64)),
            ("scale", Value::Num(cell.scale as f64)),
            ("strategy", Value::Str(cell.strategy.key())),
        ]));
        let outcome = if let Some(prev) = self.store.load(&fingerprint, None) {
            Outcome::Done(prev, true)
        } else if job.cancelled() || self.aborting() {
            Outcome::Discarded
        } else {
            self.execute_cell(job, idx, &cell, &fingerprint)
        };
        let verdict = match outcome {
            Outcome::Done(result, cached) => {
                job.events.push_value(&Value::obj(vec![
                    ("type", Value::Str("cell_done".into())),
                    ("cell", Value::Num(idx as f64)),
                    ("cached", Value::Bool(cached)),
                    ("summary", result.summary.to_json()),
                ]));
                job.results.lock().expect("job results lock")[idx] = Some(result);
                Ok(cached)
            }
            Outcome::Discarded => Err(None),
            Outcome::Failed(msg) => Err(Some(msg)),
        };
        let mut inner = self.inner.lock().expect("scheduler lock");
        let entry = inner.entries.get_mut(&job.id).expect("running entry");
        entry.running -= 1;
        match verdict {
            Ok(cached) => {
                entry.done += 1;
                if cached {
                    entry.cached += 1;
                }
            }
            Err(None) => {}
            Err(Some(msg)) => {
                if entry.error.is_none() {
                    entry.error = Some(msg);
                }
                entry.pending.clear();
            }
        }
        let terminal =
            entry.pending.is_empty() || entry.job.cancelled() || entry.error.is_some();
        let finalize = entry.running == 0 && terminal && !entry.finished;
        if finalize {
            entry.finished = true;
        }
        let state = entry.state().to_string();
        let events = Arc::clone(&job.events);
        drop(inner);
        if finalize {
            // Journal the terminal transition so a restart does not
            // replay a finished job.
            if let Some(journal) = &self.journal {
                let _ = journal.append(&Value::obj(vec![
                    ("type", Value::Str("done".into())),
                    ("id", Value::Str(job.id.clone())),
                    ("state", Value::Str(state.clone())),
                ]));
            }
            events.push_value(&job_done_event(&job.id, &state));
            events.close();
        }
        self.cv.notify_all();
        self.done_cv.notify_all();
    }
}

fn job_done_event(id: &str, state: &str) -> Value {
    Value::obj(vec![
        ("type", Value::Str("job_done".into())),
        ("job", Value::Str(id.to_string())),
        ("state", Value::Str(state.to_string())),
    ])
}

fn submit_record(job: &Job, opts: &SubmitOptions, spec_text: &str) -> Value {
    Value::obj(vec![
        ("type", Value::Str("submit".into())),
        ("id", Value::Str(job.id.clone())),
        ("priority", Value::Num(job.priority as f64)),
        ("weight", Value::Num(job.weight)),
        ("seeds", Value::Num(opts.seeds as f64)),
        ("spec", Value::Str(spec_text.to_string())),
        (
            "retries",
            match opts.retries {
                Some(r) => Value::Num(r as f64),
                None => Value::Null,
            },
        ),
        (
            "deadline_s",
            match opts.deadline_s {
                Some(d) => Value::Num(d),
                None => Value::Null,
            },
        ),
    ])
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Deterministic jittered exponential backoff: 25 ms · 2^(attempt−1),
/// scaled by a jitter in [0.5, 1.5) that is a pure hash of
/// `(job, cell, attempt)` — no wall-clock randomness, so retry traces
/// reproduce — capped at 2 s.
fn backoff_delay(job_id: &str, cell: usize, attempt: usize) -> Duration {
    let h = u64::from_str_radix(
        &content_hash(&format!("{job_id}/{cell}/{attempt}"))[..16],
        16,
    )
    .unwrap_or(0);
    let jitter = 0.5 + (h % 1024) as f64 / 1024.0;
    let base = 25.0 * (1u64 << (attempt.saturating_sub(1)).min(6)) as f64;
    Duration::from_millis((base * jitter).min(2_000.0) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SgdFlavor;
    use crate::dbench::ExperimentSpec;

    fn tiny_plan(seed: u64, cells: usize) -> SessionPlan {
        let mut s = ExperimentSpec::resnet20_analog();
        s.scales = vec![4];
        s.epochs = 1;
        s.seed = seed;
        s.max_iters_per_epoch = Some(1);
        s.threads = 1;
        s.flavors = vec![SgdFlavor::DecentralizedRing];
        let mut plan = SessionPlan::from_spec(&s);
        for _ in 1..cells {
            let cfg = s.train_config(4);
            plan.push_cell(4, seed, crate::dbench::StrategyRef::Flavor(SgdFlavor::DecentralizedRing), cfg);
        }
        plan
    }

    fn tiny_spec_text(seed: u64) -> String {
        format!(
            "base = \"resnet20\"\nname = \"tiny\"\nseed = {seed}\nscales = [4]\n\
             epochs = 1\nmax_iters_per_epoch = 1\nthreads = 1\nflavors = [\"d_ring\"]\n\
             metrics_every = 1\neval_every_epochs = 100\n"
        )
    }

    fn paused_scheduler(tag: &str) -> (Arc<Scheduler>, std::path::PathBuf) {
        let dir = crate::util::scratch_dir(tag).unwrap();
        let store = Arc::new(ResultStore::open(&dir).unwrap());
        (Scheduler::start(store, 1, true), dir)
    }

    #[test]
    fn job_ids_are_deterministic_with_dedup_suffixes() {
        let (sched, dir) = paused_scheduler("sched_ids");
        let a = sched.submit("a".into(), 0, 1.0, tiny_plan(1, 1)).unwrap();
        let b = sched.submit("b".into(), 0, 1.0, tiny_plan(1, 1)).unwrap();
        let c = sched.submit("c".into(), 0, 1.0, tiny_plan(2, 1)).unwrap();
        assert!(a.id.starts_with('j') && a.id.len() == 13, "{}", a.id);
        assert_eq!(b.id, format!("{}-2", a.id), "identical submission dedups");
        assert_ne!(c.id, a.id, "different seed, different id");
        assert!(!c.id.starts_with(&a.id), "{} vs {}", c.id, a.id);
        sched.shutdown(true);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn idempotent_submission_returns_the_existing_job() {
        let (sched, dir) = paused_scheduler("sched_idem");
        let opts = SubmitOptions { idempotent: true, ..SubmitOptions::default() };
        let a = sched.submit_spec(&tiny_spec_text(9), &opts).unwrap();
        let b = sched.submit_spec(&tiny_spec_text(9), &opts).unwrap();
        assert_eq!(a.id, b.id, "idempotent resubmission maps to the same job");
        // Without the flag the dedup suffix separates the submissions.
        let c = sched
            .submit_spec(&tiny_spec_text(9), &SubmitOptions::default())
            .unwrap();
        assert_eq!(c.id, format!("{}-2", a.id));
        sched.shutdown(true);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_replays_pending_jobs_and_skips_finished_ones() {
        let dir = crate::util::scratch_dir("sched_journal").unwrap();
        let cfg = SchedulerConfig { journal: true, paused: true, ..SchedulerConfig::default() };
        let store = Arc::new(ResultStore::open(&dir).unwrap());
        let sched = Scheduler::start_cfg(Arc::clone(&store), cfg.clone()).unwrap();
        let finished = sched
            .submit_spec(&tiny_spec_text(32), &SubmitOptions::default())
            .unwrap();
        sched.resume();
        let status = sched
            .wait(&finished.id, Duration::from_secs(300))
            .expect("first job finishes");
        assert_eq!(status.state, "done");
        // The second job lands under a closed gate, so it is still
        // queued (journal-live) when the scheduler stops abruptly.
        sched.pause();
        let pending = sched
            .submit_spec(&tiny_spec_text(31), &SubmitOptions::default())
            .unwrap();
        sched.shutdown(false);
        drop(sched);

        // Restart on the same store: the pending job is replayed under
        // its original id, the finished one is not revived.
        let sched = Scheduler::start_cfg(Arc::clone(&store), cfg).unwrap();
        let listed = sched.list();
        assert_eq!(listed.len(), 1, "{listed:?}");
        assert_eq!(listed[0].id, pending.id, "original id survives the restart");
        assert_eq!(listed[0].state, "queued");
        assert!(sched.status(&finished.id).is_none());
        sched.resume();
        let status = sched
            .wait(&pending.id, Duration::from_secs(300))
            .expect("replayed job finishes");
        assert_eq!(status.state, "done");
        sched.shutdown(true);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pick_follows_priority_then_deficit_then_seq() {
        let (sched, dir) = paused_scheduler("sched_pick");
        let a = sched.submit("a".into(), 0, 1.0, tiny_plan(10, 4)).unwrap();
        let b = sched.submit("b".into(), 0, 2.0, tiny_plan(20, 4)).unwrap();
        // Simulate dispatching under the paused gate: pick + manual
        // accounting, never running anything.
        let mut sequence = Vec::new();
        {
            let mut inner = sched.inner.lock().unwrap();
            for _ in 0..8 {
                let id = inner.pick().expect("runnable job");
                let e = inner.entries.get_mut(&id).unwrap();
                e.pending.pop_front();
                e.dispatched += 1;
                sequence.push(if id == a.id { 'a' } else { 'b' });
            }
            assert!(inner.pick().is_none(), "both drained");
        }
        // Weight 2 gets two cells per weight-1 cell; first tie breaks
        // by submission order.
        assert_eq!(sequence.iter().collect::<String>(), "abbabbaa");
        // A higher-priority late arrival preempts everything runnable.
        let hi = sched.submit("hi".into(), 9, 1.0, tiny_plan(30, 2)).unwrap();
        let lo = sched.submit("lo".into(), -1, 1.0, tiny_plan(40, 2)).unwrap();
        {
            let mut inner = sched.inner.lock().unwrap();
            assert_eq!(inner.pick(), Some(hi.id.clone()));
            let e = inner.entries.get_mut(&hi.id).unwrap();
            e.pending.clear();
            assert_eq!(inner.pick(), Some(lo.id.clone()));
        }
        sched.shutdown(true);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cancelling_a_queued_job_finalizes_it_immediately() {
        let (sched, dir) = paused_scheduler("sched_cancel");
        let job = sched.submit("x".into(), 0, 1.0, tiny_plan(3, 2)).unwrap();
        let status = sched.cancel(&job.id).expect("known job");
        assert_eq!(status.state, "cancelled");
        assert_eq!(status.done, 0);
        assert!(job.events.is_closed(), "stream terminates");
        let (lines, _) = job.events.read_from(0);
        assert!(lines.last().unwrap().contains("job_done"), "{lines:?}");
        assert!(sched.cancel("nope").is_none());
        // The results document reflects the truncation.
        let v = job.results_json();
        assert_eq!(v.get("complete"), Some(&Value::Bool(false)));
        sched.shutdown(true);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn submit_validates_inputs() {
        let (sched, dir) = paused_scheduler("sched_validate");
        let mut empty = tiny_plan(1, 1);
        empty.cells.clear();
        assert!(sched.submit("e".into(), 0, 1.0, empty).is_err());
        assert!(sched.submit("w".into(), 0, 0.0, tiny_plan(1, 1)).is_err());
        assert!(sched.submit("w".into(), 0, -2.0, tiny_plan(1, 1)).is_err());
        sched.shutdown(true);
        assert!(
            sched.submit("late".into(), 0, 1.0, tiny_plan(1, 1)).is_err(),
            "no submissions after shutdown"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn backoff_is_deterministic_jittered_and_capped() {
        let a = backoff_delay("j1", 0, 1);
        assert_eq!(a, backoff_delay("j1", 0, 1), "pure function of its inputs");
        assert_ne!(a, backoff_delay("j1", 0, 2), "jitter varies per attempt");
        assert!(a >= Duration::from_millis(12) && a <= Duration::from_millis(38), "{a:?}");
        assert!(backoff_delay("j1", 3, 50) <= Duration::from_secs(2), "capped");
    }
}
