//! The job journal: a write-ahead log that makes the experiment
//! service's queue durable across server crashes.
//!
//! Every submission, cancellation and terminal state transition is
//! appended as one framed record to `<store>/journal/wal.log` and
//! fsynced before the caller proceeds — so a server that dies (even
//! `kill -9` mid-write) can replay the log on restart, re-enqueue every
//! job that had not reached a terminal state, and re-run its
//! unfinished cells. Finished cells live in the content-addressed
//! [`ResultStore`](super::ResultStore), so replayed jobs converge to
//! byte-identical results without recomputing anything that completed.
//!
//! ## Frame format
//!
//! ```text
//! [len: u64 LE] [checksum: u64 LE] [payload: len bytes of JSON]
//! ```
//!
//! The checksum is FNV-1a over the payload, passed through the
//! SplitMix64 finalizer (the same construction as
//! [`content_hash`](super::content_hash)'s lanes). Replay stops at the
//! first frame that is truncated or fails its checksum — a torn tail
//! from a crash mid-append costs that one record, never the log.
//!
//! ## Record schema (`type` discriminates)
//!
//! ```text
//! {"type":"submit","id":"j…","priority":p,"weight":w,"seeds":k,
//!  "spec":"<verbatim spec text>","retries":r|null,"deadline_s":d|null}
//! {"type":"cancel","id":"j…"}
//! {"type":"done","id":"j…","state":"done|failed|cancelled"}
//! ```
//!
//! A job is **live** iff it has a `submit` record and no `cancel`/`done`
//! record. On startup the scheduler compacts the log down to exactly
//! the live submissions it re-enqueued, so the journal's size is
//! bounded by the live queue, not by server uptime.

use crate::error::{AdaError, Result};
use crate::util::json::Value;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// FNV-1a + SplitMix64 finalizer over `bytes` — the frame checksum.
fn frame_checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    h
}

/// Upper bound on one record's payload — a parsed length beyond this is
/// treated as frame corruption rather than attempted as an allocation.
const MAX_RECORD_BYTES: u64 = 16 * 1024 * 1024;

/// The append-only, fsync-per-record job journal. All methods take
/// `&self`; appends from concurrent request handlers serialize on an
/// internal lock.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

impl Journal {
    /// Open (creating if needed) the journal under directory `dir`.
    pub fn open(dir: &Path) -> Result<Journal> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join("wal.log");
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(Journal { path, file: Mutex::new(file) })
    }

    /// The log file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record and fsync it. The record is durable when this
    /// returns `Ok`.
    pub fn append(&self, record: &Value) -> Result<()> {
        let payload = record.to_string().into_bytes();
        let mut frame = Vec::with_capacity(16 + payload.len());
        frame.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        frame.extend_from_slice(&frame_checksum(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        let mut file = self.file.lock().expect("journal lock");
        file.write_all(&frame)?;
        file.sync_data()?;
        Ok(())
    }

    /// Read every intact record in append order. Stops silently at the
    /// first truncated or checksum-failing frame (the torn tail of a
    /// crash mid-append); a missing file is an empty journal.
    pub fn replay(&self) -> Vec<Value> {
        read_records(&self.path)
    }

    /// Atomically replace the log with exactly `records` (startup
    /// compaction): the new content is written to a temp file, fsynced,
    /// and renamed over the old log, then the append handle is
    /// reopened. A crash at any point leaves either the old or the new
    /// log intact.
    pub fn rewrite(&self, records: &[Value]) -> Result<()> {
        let tmp = self.path.with_extension("log.tmp");
        {
            let mut out = File::create(&tmp)?;
            for record in records {
                let payload = record.to_string().into_bytes();
                out.write_all(&(payload.len() as u64).to_le_bytes())?;
                out.write_all(&frame_checksum(&payload).to_le_bytes())?;
                out.write_all(&payload)?;
            }
            out.sync_all()?;
        }
        let mut file = self.file.lock().expect("journal lock");
        std::fs::rename(&tmp, &self.path)?;
        *file = OpenOptions::new().create(true).append(true).open(&self.path)?;
        Ok(())
    }
}

/// The tolerant frame reader behind [`Journal::replay`].
fn read_records(path: &Path) -> Vec<Value> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            if f.read_to_end(&mut bytes).is_err() {
                return Vec::new();
            }
        }
        Err(_) => return Vec::new(),
    }
    let mut records = Vec::new();
    let mut pos = 0usize;
    while bytes.len() - pos >= 16 {
        let len = u64::from_le_bytes(bytes[pos..pos + 8].try_into().expect("8 bytes"));
        let sum = u64::from_le_bytes(bytes[pos + 8..pos + 16].try_into().expect("8 bytes"));
        if len > MAX_RECORD_BYTES {
            break;
        }
        let len = len as usize;
        let start = pos + 16;
        let Some(payload) = bytes.get(start..start + len) else {
            break; // truncated tail
        };
        if frame_checksum(payload) != sum {
            break; // corrupt frame: stop, keep everything before it
        }
        if let Ok(v) = Value::parse(&String::from_utf8_lossy(payload)) {
            records.push(v);
        }
        pos = start + len;
    }
    records
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(kind: &str, id: &str) -> Value {
        Value::obj(vec![
            ("type", Value::Str(kind.into())),
            ("id", Value::Str(id.into())),
        ])
    }

    #[test]
    fn append_replay_roundtrip_in_order() {
        let dir = crate::util::scratch_dir("journal_rt").unwrap();
        let j = Journal::open(&dir).unwrap();
        assert!(j.replay().is_empty(), "fresh journal is empty");
        j.append(&record("submit", "j1")).unwrap();
        j.append(&record("done", "j1")).unwrap();
        j.append(&record("submit", "j2")).unwrap();
        let back = j.replay();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0].str_field("type").unwrap(), "submit");
        assert_eq!(back[1].str_field("id").unwrap(), "j1");
        assert_eq!(back[2].str_field("id").unwrap(), "j2");
        // A reopened journal replays the same records and keeps
        // appending after them.
        drop(j);
        let j = Journal::open(&dir).unwrap();
        assert_eq!(j.replay().len(), 3);
        j.append(&record("cancel", "j2")).unwrap();
        assert_eq!(j.replay().len(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_tolerated() {
        let dir = crate::util::scratch_dir("journal_torn").unwrap();
        let j = Journal::open(&dir).unwrap();
        j.append(&record("submit", "j1")).unwrap();
        j.append(&record("submit", "j2")).unwrap();
        // Simulate a crash mid-append: chop bytes off the last frame.
        let path = j.path().to_path_buf();
        drop(j);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let j = Journal::open(&dir).unwrap();
        let back = j.replay();
        assert_eq!(back.len(), 1, "only the intact prefix survives");
        assert_eq!(back[0].str_field("id").unwrap(), "j1");
        // Appends continue after the torn tail is replaced on rewrite.
        j.rewrite(&back).unwrap();
        j.append(&record("submit", "j3")).unwrap();
        assert_eq!(j.replay().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_corruption_stops_replay_at_the_bad_frame() {
        let dir = crate::util::scratch_dir("journal_sum").unwrap();
        let j = Journal::open(&dir).unwrap();
        j.append(&record("submit", "j1")).unwrap();
        j.append(&record("submit", "j2")).unwrap();
        j.append(&record("submit", "j3")).unwrap();
        let path = j.path().to_path_buf();
        drop(j);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one payload byte in the middle record (frame 2 starts
        // after frame 1 = 16 + payload).
        let first_len =
            u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
        let second_payload = 16 + first_len + 16;
        bytes[second_payload] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let back = Journal::open(&dir).unwrap().replay();
        assert_eq!(back.len(), 1, "replay must stop at the corrupt frame");
        assert_eq!(back[0].str_field("id").unwrap(), "j1");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rewrite_compacts_atomically() {
        let dir = crate::util::scratch_dir("journal_compact").unwrap();
        let j = Journal::open(&dir).unwrap();
        for i in 0..10 {
            j.append(&record("submit", &format!("j{i}"))).unwrap();
        }
        let live = vec![record("submit", "j7")];
        j.rewrite(&live).unwrap();
        let back = j.replay();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].str_field("id").unwrap(), "j7");
        // The handle keeps appending to the compacted log.
        j.append(&record("done", "j7")).unwrap();
        assert_eq!(j.replay().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
