//! `dbench` — the benchmarking-framework CLI of §3: runs the controlled
//! experiment grids (workload × scale × SGD implementation), writes
//! per-iteration JSONL plus summary tables, and prints the §3.3 variance
//! ranking analysis.
//!
//! ```text
//! dbench list                                   # available specs
//! dbench run --app resnet20 --scales 8,16 --epochs 4
//! dbench run --spec configs/fig3_resnet20.toml  # from TOML
//! dbench run --app resnet20 --threads 8 --fused # multi-core fast path
//! dbench ada --app densenet --workers 16        # Fig 7-style comparison
//! ```

use ada_dist::config::LauncherConfig;
use ada_dist::coordinator::{strategy, SgdFlavor};
use ada_dist::dbench::{
    format_stats_table, format_table, rank_analysis, run_experiment, seed_stats,
    ExperimentSpec, SessionPlan, StrategyRef, TopologyRef,
};
use ada_dist::optim::ScalingRule;
use ada_dist::serve::{http_request, http_stream_lines, start, ServeConfig};
use ada_dist::util::cli::Args;
use std::io::Write as _;

type CliResult = Result<(), Box<dyn std::error::Error>>;

const USAGE: &str = "\
dbench <command> [options]
  list        built-in application specs
  strategies  registered SGD strategy names (the open registry)
  topologies  registered topology policy names (the topology registry)
  run         experiment grid (Fig 2/3/4/5-style), on the SessionPlan pipeline
    --app resnet20|resnet50|densenet|lstm | --spec FILE.toml
    --scales 8,16,32 --epochs N --max-iters N --sqrt-scaling --save-records
    --topology name[:k=v,...]   override every decentralized cell's graph
                        policy with one from the topology registry
    --strategy name[:k=v,...]   add a registry strategy to the grid, e.g.
                        compressed_gossip:codec=bf16,k=65536 (repeatable
                        via spec TOML `strategies = [...]`)
    --seeds K           run every cell K times with derived seeds and
                        report mean ± stderr per cell (variance of the
                        estimate; the paper reports single seeds)
    --threads N (0 = all cores; bit-identical results)  --fused
    --pipeline          overlap gossip with compute bucket-by-bucket
                        (bit-identical to phased)  --bucket-kb N (0 = 256 KB)
    --faults k=v,...    deterministic fault plan for decentralized cells
                        (seed, drop_prob, straggler_prob, straggler_iters,
                        straggler_slowdown, link_jitter, crash=n@from:to;..,
                        recover_dir); same keys as the spec [faults] table
    --staleness-bound N fault-injected gossip mixes peer rows up to N
                        rounds old (0 = only this round's deliveries)
    --cell-parallel N   run up to N grid cells concurrently (bounded by
                        cores; auto-threaded cells then run 1 thread
                        each — results identical either way)
    --resume-dir PATH   persist each finished cell; a rerun reuses cells
                        whose seed/epochs/scale still match
  ada         Fig 7-style comparison: Ada vs C_complete/D_ring/D_torus
    --app NAME --workers N --epochs N --k0 N --gamma-k F
    --topology name[:k=v,...]
  serve       long-lived multi-tenant experiment service (HTTP/1.1)
    --addr HOST:PORT (default 127.0.0.1:7070) --store DIR --workers N
    --hold              start with the dispatch gate paused
    --no-journal        disable the job journal (on by default under
                        --store; a restarted server replays it)
    --retries N         default per-cell transient-failure retries
    --deadline-s F      default per-cell wall-clock deadline (0 = none)
    --max-conns N       concurrent-connection cap (503 beyond it)
  submit      POST a spec file to a running server
    --addr HOST:PORT --spec FILE.toml|FILE.json
    --priority N --weight F --seeds K
    --retries N --deadline-s F   per-job overrides
    --idempotent        resubmitting the same spec returns the
                        existing job instead of a -N duplicate
  status      job status (--job ID) or all jobs
  results     fetch a job's results document   --job ID
  stream      tail a job's JSONL metric stream --job ID
  cancel      cancel a job                     --job ID
  shutdown    stop a running server (--no-drain cancels in-flight cells)
  (global) --config PATH   launcher TOML";

fn builtin(app: &str) -> Result<ExperimentSpec, String> {
    Ok(match app {
        "resnet20" => ExperimentSpec::resnet20_analog(),
        "resnet50" => ExperimentSpec::resnet50_analog(),
        "densenet" => ExperimentSpec::densenet_analog(),
        "lstm" => ExperimentSpec::lstm_analog(),
        other => return Err(format!("unknown app {other} (resnet20|resnet50|densenet|lstm)")),
    })
}

fn main() -> CliResult {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            "sqrt-scaling",
            "save-records",
            "fused",
            "pipeline",
            "help",
            "hold",
            "no-drain",
            "no-journal",
            "idempotent",
        ],
    )
    .map_err(|e| format!("{e}\n\n{USAGE}"))?;
    let cfg = match args.get("config") {
        Some(p) => LauncherConfig::from_file(std::path::Path::new(p))
            .map_err(|e| format!("loading launcher config: {e}"))?,
        None => LauncherConfig::default(),
    };

    match args.command.as_deref() {
        Some("list") => {
            for spec in ExperimentSpec::four_applications() {
                println!(
                    "{:<28} workload={:<16} scales={:?} epochs={}",
                    spec.name,
                    spec.workload.name(),
                    spec.scales,
                    spec.epochs
                );
            }
            Ok(())
        }
        Some("strategies") => {
            for name in strategy::registry().names() {
                println!("{name}");
            }
            Ok(())
        }
        Some("topologies") => {
            for name in ada_dist::topology::registry().names() {
                println!("{name}");
            }
            Ok(())
        }
        Some("run") => cmd_run(&args, &cfg),
        Some("ada") => cmd_ada(&args, &cfg),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("status") => cmd_client_get(&args, "status"),
        Some("results") => cmd_client_get(&args, "results"),
        Some("stream") => cmd_stream(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("shutdown") => cmd_shutdown(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_run(args: &Args, cfg: &LauncherConfig) -> CliResult {
    let mut spec = match (args.get("app"), args.get("spec")) {
        (Some(app), None) => builtin(app)?,
        (None, Some(path)) => ExperimentSpec::from_toml_file(std::path::Path::new(path))?,
        _ => return Err(format!("pass exactly one of --app or --spec\n\n{USAGE}").into()),
    };
    if let Some(scales) = args.get_list::<usize>("scales")? {
        spec.scales = scales;
    }
    if let Some(e) = args.get_opt::<usize>("epochs")? {
        spec.epochs = e;
    }
    if let Some(m) = args.get_opt::<usize>("max-iters")? {
        spec.max_iters_per_epoch = Some(m);
    }
    if args.has_flag("sqrt-scaling") {
        spec.scaling = ScalingRule::Sqrt;
    }
    spec.threads = args.threads(cfg.threads)?;
    if args.has_flag("fused") {
        spec.fused = true;
    }
    if args.has_flag("pipeline") {
        spec.pipeline = true;
    }
    spec.bucket_kb = args.get_parse("bucket-kb", spec.bucket_kb)?;
    apply_fault_args(args, &mut spec)?;
    if let Some(t) = args.get("topology") {
        spec.topology = Some(TopologyRef::parse(t)?);
    }
    if let Some(s) = args.get("strategy") {
        // Joins the grid alongside the spec's flavors, same as a TOML
        // `strategies = [...]` entry.
        spec.strategies.push(StrategyRef::parse(s)?);
    }
    let seeds: usize = args.get_parse("seeds", 1)?;
    let mut plan = SessionPlan::from_spec(&spec);
    plan.expand_seeds(seeds);
    plan.parallel = args.get_parse("cell-parallel", 1)?;
    plan.resume_dir = args.get("resume-dir").map(std::path::PathBuf::from);
    let t0 = std::time::Instant::now();
    let cells = plan.run()?;
    if seeds > 1 {
        println!(
            "{}",
            format_stats_table(
                &format!("{} × {seeds} seeds ({:.1?})", spec.name, t0.elapsed()),
                &seed_stats(&cells)
            )
        );
    } else {
        println!(
            "{}",
            format_table(&format!("{} ({:.1?})", spec.name, t0.elapsed()), &cells)
        );
    }
    // Per-scale ranking analysis (Fig. 5). Skipped in seeds mode: the
    // replicated cells would compete as separate entrants (ranks
    // 1..K·m instead of 1..m) while merging counts under one name —
    // not comparable to the single-seed figure.
    if seeds <= 1 {
        for &scale in &spec.scales {
            let scale_cells: Vec<_> = cells.iter().filter(|c| c.scale == scale).collect();
            if scale_cells.len() < 2 {
                continue;
            }
            let rank = rank_analysis(scale_cells.iter().copied());
            println!("variance ranks @ {scale} workers (1 = lowest variance):");
            for (name, mean) in rank.ordering() {
                println!("  {name:<16} mean rank {mean:.2}");
            }
        }
    }
    if args.has_flag("save-records") {
        let out = cfg.ensure_output_dir()?;
        for c in &cells {
            let path = out.join(format!("{}_{}_{}.jsonl", spec.name, c.scale, c.flavor));
            let mut file = std::fs::File::create(&path)?;
            for r in c.recorder.records() {
                writeln!(file, "{}", r.to_json().to_string())?;
            }
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

/// `--faults k=v,…` / `--staleness-bound N` → the spec's fault plane
/// (layered over any `[faults]` the spec TOML already carries).
fn apply_fault_args(args: &Args, spec: &mut ExperimentSpec) -> CliResult {
    if let Some(kv) = args.get("faults") {
        let table = ada_dist::util::params::ParamTable::parse_kv(kv)?;
        spec.faults = Some(ada_dist::simnet::FaultPlan::from_table(&table)?);
    }
    spec.staleness_bound = args.get_parse("staleness-bound", spec.staleness_bound)?;
    Ok(())
}

fn server_addr(args: &Args) -> String {
    args.get_or("addr", "127.0.0.1:7070").to_string()
}

fn print_body(body: &[u8]) {
    println!("{}", String::from_utf8_lossy(body).trim_end());
}

fn cmd_serve(args: &Args) -> CliResult {
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        addr: server_addr(args),
        store_dir: args.get_or("store", "dbench_store").to_string(),
        workers: args.get_parse("workers", 1)?,
        hold: args.has_flag("hold"),
        journal: !args.has_flag("no-journal"),
        retries: args.get_parse("retries", defaults.retries)?,
        deadline_s: args.get_parse("deadline-s", defaults.deadline_s)?,
        max_conns: args.get_parse("max-conns", defaults.max_conns)?,
        ..defaults
    };
    let mut server = start(&cfg)?;
    println!(
        "dbench service listening on http://{} (store {}, {} worker{}{}{})",
        server.addr,
        cfg.store_dir,
        cfg.workers.max(1),
        if cfg.workers.max(1) == 1 { "" } else { "s" },
        if cfg.journal { ", journaled" } else { "" },
        if cfg.hold { ", dispatch paused" } else { "" },
    );
    println!("stop with: dbench shutdown --addr {}", server.addr);
    server.join();
    Ok(())
}

fn cmd_submit(args: &Args) -> CliResult {
    let path = args
        .get("spec")
        .ok_or_else(|| format!("submit needs --spec FILE\n\n{USAGE}"))?;
    let body = std::fs::read(path)?;
    let mut query = Vec::new();
    for key in ["priority", "weight", "seeds", "retries"] {
        if let Some(v) = args.get(key) {
            query.push(format!("{key}={v}"));
        }
    }
    if let Some(v) = args.get("deadline-s") {
        query.push(format!("deadline_s={v}"));
    }
    if args.has_flag("idempotent") {
        query.push("idempotent=true".to_string());
    }
    let target = if query.is_empty() {
        "/jobs".to_string()
    } else {
        format!("/jobs?{}", query.join("&"))
    };
    let (code, resp) = http_request(&server_addr(args), "POST", &target, Some(&body))?;
    print_body(&resp);
    if code != 200 {
        return Err(format!("submit failed (HTTP {code})").into());
    }
    Ok(())
}

fn cmd_client_get(args: &Args, what: &str) -> CliResult {
    let path = match (what, args.get("job")) {
        ("status", None) => "/jobs".to_string(),
        ("status", Some(id)) => format!("/jobs/{id}"),
        (_, Some(id)) => format!("/jobs/{id}/{what}"),
        (_, None) => return Err(format!("{what} needs --job ID\n\n{USAGE}").into()),
    };
    let (code, resp) = http_request(&server_addr(args), "GET", &path, None)?;
    print_body(&resp);
    if code != 200 {
        return Err(format!("{what} failed (HTTP {code})").into());
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> CliResult {
    let id = args
        .get("job")
        .ok_or_else(|| format!("stream needs --job ID\n\n{USAGE}"))?;
    let code = http_stream_lines(&server_addr(args), &format!("/jobs/{id}/stream"), |line| {
        println!("{line}");
    })?;
    if code != 200 {
        return Err(format!("stream failed (HTTP {code})").into());
    }
    Ok(())
}

fn cmd_cancel(args: &Args) -> CliResult {
    let id = args
        .get("job")
        .ok_or_else(|| format!("cancel needs --job ID\n\n{USAGE}"))?;
    let (code, resp) =
        http_request(&server_addr(args), "POST", &format!("/jobs/{id}/cancel"), None)?;
    print_body(&resp);
    if code != 200 {
        return Err(format!("cancel failed (HTTP {code})").into());
    }
    Ok(())
}

fn cmd_shutdown(args: &Args) -> CliResult {
    let drain = !args.has_flag("no-drain");
    let (code, resp) = http_request(
        &server_addr(args),
        "POST",
        &format!("/shutdown?drain={drain}"),
        None,
    )?;
    print_body(&resp);
    if code != 200 {
        return Err(format!("shutdown failed (HTTP {code})").into());
    }
    Ok(())
}

fn cmd_ada(args: &Args, cfg: &LauncherConfig) -> CliResult {
    let app = args.get_or("app", "resnet20");
    let workers: usize = args.get_parse("workers", 16)?;
    let epochs: usize = args.get_parse("epochs", 8)?;
    let k0: Option<usize> = args.get_opt("k0")?;
    let gamma_k: f64 = args.get_parse("gamma-k", 1.0)?;
    let mut spec = builtin(app)?;
    spec.scales = vec![workers];
    spec.epochs = epochs;
    spec.threads = args.threads(cfg.threads)?;
    if args.has_flag("fused") {
        spec.fused = true;
    }
    if args.has_flag("pipeline") {
        spec.pipeline = true;
    }
    spec.bucket_kb = args.get_parse("bucket-kb", spec.bucket_kb)?;
    apply_fault_args(args, &mut spec)?;
    spec.flavors = vec![
        SgdFlavor::CentralizedComplete,
        SgdFlavor::DecentralizedRing,
        SgdFlavor::DecentralizedTorus,
        SgdFlavor::Ada {
            k0: k0.unwrap_or(workers.saturating_sub(1).max(2)),
            gamma_k,
        },
    ];
    if let Some(t) = args.get("topology") {
        spec.topology = Some(TopologyRef::parse(t)?);
    }
    let t0 = std::time::Instant::now();
    let cells = run_experiment(&spec)?;
    println!(
        "{}",
        format_table(
            &format!("Ada comparison: {} @ {workers} ({:.1?})", spec.name, t0.elapsed()),
            &cells
        )
    );
    Ok(())
}
