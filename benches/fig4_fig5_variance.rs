//! Figures 4 & 5 — the white-box variance analysis (§3.3).
//!
//! Fig 4: gini coefficients of individual parameter tensors across
//! iterations, per SGD implementation. Paper shape: early in training
//! `D_ring` shows the highest variance and `C/D_complete` the lowest;
//! the cross-graph differences diminish as training progresses.
//!
//! Fig 5: the variance *rank* summary — per iteration each
//! implementation gets rank 1..m by gini; mean ranks reproduce the
//! ordering (C_complete lowest … D_ring highest).
//!
//! Run: `cargo bench --bench fig4_fig5_variance`.

use ada_dist::dbench::{rank_analysis, run_experiment, ExperimentSpec};
use ada_dist::util::bench::{env_flag, env_usize, Table};

fn main() {
    let full = env_flag("ADA_BENCH_FULL");
    let scale = env_usize("ADA_BENCH_SCALE", if full { 32 } else { 16 });
    let mut spec = ExperimentSpec::resnet20_analog();
    spec.scales = vec![scale];
    spec.epochs = env_usize("ADA_BENCH_EPOCHS", if full { 12 } else { 6 });
    spec.metrics_every = 1; // DBench captures every iteration
    spec.track_layers = vec![0, 1];

    let t0 = std::time::Instant::now();
    let cells = run_experiment(&spec).expect("sweep");
    println!(
        "== Fig 4: per-tensor gini across iterations ({} @ {scale} workers, {:.1?}) ==",
        spec.name,
        t0.elapsed()
    );

    // Report the gini of tracked tensor 0 in windows across the run.
    let total = cells
        .iter()
        .map(|c| c.recorder.records().len())
        .min()
        .unwrap();
    let window = (total / 5).max(1);
    let mut t = Table::new(&["flavor", "iters 1..w", "mid", "late", "whole-model late"]);
    for c in &cells {
        let tensor_gini = |range: std::ops::Range<usize>| -> f64 {
            let vals: Vec<f64> = c.recorder.records()[range.start..range.end.min(total)]
                .iter()
                .filter_map(|r| r.per_tensor_gini.first().copied())
                .collect();
            if vals.is_empty() {
                0.0
            } else {
                vals.iter().sum::<f64>() / vals.len() as f64
            }
        };
        t.row(vec![
            c.flavor.clone(),
            format!("{:.6}", tensor_gini(1..window + 1)),
            format!("{:.6}", tensor_gini(total / 2..total / 2 + window)),
            format!("{:.6}", tensor_gini(total - window..total)),
            format!("{:.6}", c.summary.late_gini),
        ]);
    }
    println!("{}", t.render());
    println!(
        "expected shape: D_ring largest early gini, C/D_complete smallest;\n\
         all columns shrink left→right and converge across flavors.\n"
    );

    // Fig 5: rank summary over the whole run.
    let ranks = rank_analysis(&cells);
    println!("== Fig 5: variance rank summary (1 = lowest variance) ==");
    let mut t = Table::new(&["flavor", "mean rank", "observations"]);
    for (name, mean) in ranks.ordering() {
        let count = ranks.count(&name);
        t.row(vec![name, format!("{mean:.2}"), count.to_string()]);
    }
    println!("{}", t.render());
    println!("expected shape: ascending mean rank ≈ C_complete, D_complete, D_exponential/D_torus, D_ring.");
}
