//! Gossip mixing engine benchmarks — the L3 hot path.
//!
//! Sections:
//!   1. native sparse engine vs the O(n²P) dense reference
//!   2. **threads × graph × P sweep**: serial-vs-parallel speedup of the
//!      blocked SpMM, and fused gossip+SGD vs split mix-then-step
//!   3. **pool vs scoped**: per-call fork-join dispatch cost of the
//!      persistent worker pool against a per-call scoped-thread spawn
//!      (what the engine did before PR 2)
//!   4. **reduce vs serial variance**: the trainer's per-replica L2
//!      variance capture as a pooled deterministic tiled reduction
//!      against the old serial O(n·P) pass
//!   5. **simd vs scalar**: the explicit AVX2 kernel layer against its
//!      fixed-8-lane scalar fallback (axpy, the fused mix_step, and the
//!      sum-of-squares reduction) at P ∈ {2^16 … 2^22} — results are
//!      bit-identical, so the sweep is pure wall-clock
//!   6. **pipeline vs phased**: the overlapped bucketed gossip pipeline
//!      (PR 6) against the phase-ordered local-step-then-mix iteration,
//!      with a synthetic per-row local step standing in for compute —
//!      sweeps bucket_kb × threads × graph; results are bit-identical
//!      so the sweep is pure wall-clock
//!   7. **stale vs fresh mixing**: the bounded-staleness path
//!      (`ingest_stale` + `mix_stale`, PR 7) against the live-row `mix`
//!      under seeded message-drop weather — measures what the fault
//!      plane's buffer bookkeeping costs per round
//!   8. **compressed vs dense exchange**: the bf16/f16 codec rounds
//!      (`mix_codec`) and the top-k error-feedback path (`sparsify` +
//!      `mix_from`) against the dense f32 mix, with modeled Summit
//!      wire time/bytes per round from the SimNet α–β model
//!   9. the L1 Pallas kernel via PJRT (pjrt builds with artifacts)
//!
//! Sections 2–8 are written to `BENCH_gossip.json` at the repo root.
//! Results are bit-identical across thread counts and across the
//! SIMD/scalar paths (asserted in `rust/tests/exec_determinism.rs`), so
//! every sweep is purely wall-clock.
//!
//! Run: `cargo bench --bench gossip_bench`.
//! Knobs: `ADA_BENCH_ITERS` (default 30), `ADA_BENCH_FULL=1` (adds the
//! paper-scale n=64, P=1M cells to the sweep; they are included by
//! default too — the flag raises their iteration count), `ADA_SIMD=
//! scalar` (force the fallback everywhere).

use ada_dist::compress::topk::sparsify_row;
use ada_dist::compress::Codec;
use ada_dist::exec::{simd, ExecEngine};
use ada_dist::gossip::{mix_dense_reference, GossipEngine};
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::metrics::{l2_norm, per_replica_l2_norms_pooled, VarianceReport};
use ada_dist::optim::SgdState;
use ada_dist::simnet::{ClusterSpec, FaultPlan, SimNet};
use ada_dist::util::bench::{bench, env_flag, env_usize, fmt_duration, Table};
use ada_dist::util::json::Value;
use ada_dist::util::rng::Rng;
use ada_dist::ReplicaMatrix;

fn replicas(n: usize, p: usize, seed: u64) -> ReplicaMatrix {
    let mut rng = Rng::seed_from_u64(seed);
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect();
    ReplicaMatrix::from_rows(&rows)
}

fn flat(p: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

fn main() {
    let iters = env_usize("ADA_BENCH_ITERS", 30);
    native_vs_dense(iters);
    let sweep = threads_sweep(iters);
    let pool = pool_vs_scoped(iters);
    let reduce = reduce_vs_serial_variance(iters);
    let simd_cells = simd_vs_scalar(iters);
    let pipeline = pipeline_vs_phased(iters);
    let stale = stale_vs_fresh(iters);
    let compressed = compressed_vs_dense(iters);
    write_bench_json(sweep, pool, reduce, simd_cells, pipeline, stale, compressed);
    #[cfg(feature = "pjrt")]
    hlo_section(iters);
    #[cfg(not(feature = "pjrt"))]
    println!("(pure-std build — skipping the PJRT kernel path; use --features pjrt)");
}

fn native_vs_dense(iters: usize) {
    println!("== gossip mixing: native vs dense reference ==");
    let mut t = Table::new(&["graph", "n", "P", "path", "median/round", "GB/s"]);
    for (n, p) in [(8, 2762), (16, 72000), (32, 72000), (64, 1_000_000)] {
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::Complete] {
            let g = CommGraph::build(kind, n).unwrap();
            // Bytes read+written per round on the sparse path.
            let touched = ((g.degree() + 2) * n * p * 4) as f64;
            let src = replicas(n, p, 1);
            let mut engine = GossipEngine::new();
            let mut reps = src.clone();
            let tm = bench(2, iters, || {
                engine.mix(&g, &mut reps);
            });
            t.row(vec![
                kind.to_string(),
                n.to_string(),
                p.to_string(),
                "native".into(),
                fmt_duration(tm.median),
                format!("{:.2}", touched / tm.median.as_secs_f64() / 1e9),
            ]);
            if p <= 100_000 {
                let rows = src.to_vecs();
                let tm = bench(1, (iters / 3).max(3), || {
                    std::hint::black_box(mix_dense_reference(&g, &rows));
                });
                t.row(vec![
                    kind.to_string(),
                    n.to_string(),
                    p.to_string(),
                    "dense-ref".into(),
                    fmt_duration(tm.median),
                    format!("{:.2}", touched / tm.median.as_secs_f64() / 1e9),
                ]);
            }
        }
    }
    println!("{}", t.render());
}

/// Serial-vs-parallel SpMM and fused-vs-split gossip+SGD over
/// threads × graph × P, recorded to BENCH_gossip.json.
fn threads_sweep(iters: usize) -> Vec<Value> {
    let full = env_flag("ADA_BENCH_FULL");
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("== threads × graph × P sweep (host has {cores} cores) ==");

    let graphs = [
        GraphKind::Ring,
        GraphKind::RingLattice { k: 3 },
        GraphKind::Exponential,
        GraphKind::Complete,
    ];
    let sizes: [(usize, usize); 3] = [(16, 72_000), (64, 262_144), (64, 1_000_000)];
    let thread_counts = [1usize, 2, 4, 8];

    let mut t = Table::new(&[
        "graph", "n", "P", "threads", "mix", "speedup", "split", "fused", "fused gain",
    ]);
    let mut cells: Vec<Value> = Vec::new();

    for (n, p) in sizes {
        // Big cells get fewer iterations unless ADA_BENCH_FULL=1.
        let cell_iters = if p >= 500_000 && !full { (iters / 6).max(3) } else { iters };
        for kind in graphs {
            let g = CommGraph::build(kind, n).unwrap();
            let touched = ((g.degree() + 2) * n * p * 4) as f64;
            let src = replicas(n, p, 1);
            let shared_grad = flat(p, 2);
            let mut serial_mix_s = f64::NAN;
            for threads in thread_counts {
                // -- plain mix --------------------------------------
                let mut engine = GossipEngine::with_threads(threads);
                let mut reps = src.clone();
                let t_mix = bench(1, cell_iters, || {
                    engine.mix(&g, &mut reps);
                });
                let mix_s = t_mix.median.as_secs_f64();
                if threads == 1 {
                    serial_mix_s = mix_s;
                }
                let speedup = serial_mix_s / mix_s;

                // -- split: mix + per-replica momentum step ---------
                let mut split_engine = GossipEngine::with_threads(threads);
                let mut split_reps = src.clone();
                let mut split_states: Vec<SgdState> =
                    (0..n).map(|_| SgdState::new(p, 0.9, 0.0)).collect();
                let t_split = bench(1, cell_iters, || {
                    split_engine.mix(&g, &mut split_reps);
                    for (w, s) in split_states.iter_mut().enumerate() {
                        s.step(split_reps.row_mut(w), &shared_grad, 0.01);
                    }
                });

                // -- fused gossip+SGD -------------------------------
                let mut fused_engine = GossipEngine::with_threads(threads);
                let mut fused_reps = src.clone();
                let mut fused_states: Vec<SgdState> =
                    (0..n).map(|_| SgdState::new(p, 0.9, 0.0)).collect();
                let gs = ReplicaMatrix::broadcast(n, &shared_grad);
                let t_fused = bench(1, cell_iters, || {
                    fused_engine.mix_step(&g, &mut fused_reps, &gs, &mut fused_states, 0.01);
                });

                let split_s = t_split.median.as_secs_f64();
                let fused_s = t_fused.median.as_secs_f64();
                t.row(vec![
                    kind.to_string(),
                    n.to_string(),
                    p.to_string(),
                    threads.to_string(),
                    fmt_duration(t_mix.median),
                    format!("{speedup:.2}x"),
                    fmt_duration(t_split.median),
                    fmt_duration(t_fused.median),
                    format!("{:.2}x", split_s / fused_s),
                ]);
                cells.push(Value::obj(vec![
                    ("graph", Value::Str(kind.to_string())),
                    ("n", Value::Num(n as f64)),
                    ("p", Value::Num(p as f64)),
                    ("threads", Value::Num(threads as f64)),
                    ("mix_median_s", Value::Num(mix_s)),
                    ("mix_gbps", Value::Num(touched / mix_s / 1e9)),
                    ("mix_speedup_vs_1t", Value::Num(speedup)),
                    ("split_median_s", Value::Num(split_s)),
                    ("fused_median_s", Value::Num(fused_s)),
                    ("fused_speedup_vs_split", Value::Num(split_s / fused_s)),
                    ("iters", Value::Num(cell_iters as f64)),
                ]));
            }
        }
    }
    println!("{}", t.render());
    println!(
        "(speedup = mix vs the same engine at 1 thread; fused gain = split\n\
         mix+step vs the fused kernel at the same thread count)"
    );
    cells
}

/// Per-call fork-join dispatch cost: the persistent parked pool against
/// a per-call `std::thread::scope` spawn (the pre-PR-2 engine). The
/// jobs are near-trivial so the measurement isolates dispatch overhead
/// — the cost the pool removes from every small-P/high-frequency round.
fn pool_vs_scoped(iters: usize) -> Vec<Value> {
    println!("== fork-join dispatch: persistent pool vs per-call scoped spawn ==");
    let calls = (iters * 20).max(200);
    let mut t = Table::new(&["threads", "pool/call", "scoped/call", "spawn cost removed"]);
    let mut cells = Vec::new();
    for threads in [2usize, 4, 8] {
        let engine = ExecEngine::new(threads);
        let mut sink = vec![0u64; threads];
        let t_pool = bench(calls / 4, calls, || {
            let jobs: Vec<_> = sink
                .iter_mut()
                .enumerate()
                .map(|(i, s)| move || *s = i as u64 + 1)
                .collect();
            engine.run_jobs(jobs);
        });
        let t_scoped = bench(calls / 4, calls, || {
            // What ExecEngine::run_jobs did before the pool: job 0 on
            // the caller, one scoped thread spawned per remaining job.
            let mut it = sink.iter_mut().enumerate();
            let first = it.next();
            std::thread::scope(|scope| {
                for (i, s) in it {
                    scope.spawn(move || *s = i as u64 + 1);
                }
                if let Some((i, s)) = first {
                    *s = i as u64 + 1;
                }
            });
        });
        std::hint::black_box(&mut sink);
        let (pool_s, scoped_s) = (t_pool.median.as_secs_f64(), t_scoped.median.as_secs_f64());
        t.row(vec![
            threads.to_string(),
            fmt_duration(t_pool.median),
            fmt_duration(t_scoped.median),
            format!("{:.2}x", scoped_s / pool_s),
        ]);
        cells.push(Value::obj(vec![
            ("threads", Value::Num(threads as f64)),
            ("pool_median_s", Value::Num(pool_s)),
            ("scoped_median_s", Value::Num(scoped_s)),
            ("scoped_over_pool", Value::Num(scoped_s / pool_s)),
            ("calls", Value::Num(calls as f64)),
        ]));
    }
    println!("{}", t.render());
    cells
}

/// The trainer's variance capture (per-replica L2 norms + §3.3 stats),
/// serial pass vs the pooled deterministic tiled reduction — the
/// monitoring path the paper argues must be as cheap as the mixing
/// path.
fn reduce_vs_serial_variance(iters: usize) -> Vec<Value> {
    println!("== variance capture: serial O(n·P) pass vs pooled tiled reduction ==");
    let (n, p) = (64usize, 262_144usize);
    let reps = replicas(n, p, 3);
    let serial = bench(2, iters, || {
        let norms: Vec<f64> = reps.rows().map(l2_norm).collect();
        std::hint::black_box(VarianceReport::of(&norms));
    });
    let serial_s = serial.median.as_secs_f64();
    let mut t = Table::new(&["n", "P", "threads", "serial", "pooled", "speedup"]);
    let mut cells = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let engine = ExecEngine::new(threads);
        let pooled = bench(2, iters, || {
            let norms = per_replica_l2_norms_pooled(&engine, &reps, 0..p);
            std::hint::black_box(VarianceReport::of(&norms));
        });
        let pooled_s = pooled.median.as_secs_f64();
        t.row(vec![
            n.to_string(),
            p.to_string(),
            threads.to_string(),
            fmt_duration(serial.median),
            fmt_duration(pooled.median),
            format!("{:.2}x", serial_s / pooled_s),
        ]);
        cells.push(Value::obj(vec![
            ("n", Value::Num(n as f64)),
            ("p", Value::Num(p as f64)),
            ("threads", Value::Num(threads as f64)),
            ("serial_median_s", Value::Num(serial_s)),
            ("pooled_median_s", Value::Num(pooled_s)),
            ("speedup_vs_serial", Value::Num(serial_s / pooled_s)),
            ("iters", Value::Num(iters as f64)),
        ]));
    }
    println!("{}", t.render());
    println!("(pooled results are bit-identical at every thread count — the sweep is pure wall-clock)");
    cells
}

/// The explicit SIMD layer vs its fixed-8-lane scalar fallback: axpy,
/// the fused mix_step (single-threaded, so the measurement isolates the
/// kernels, not the fan-out), and the f64 sum-of-squares reduction, at
/// P from 2^16 to 2^22. Both paths produce identical bits; the sweep
/// measures what the explicit vectorization buys over the fallback (on
/// AVX2 hosts — elsewhere both rows time the same scalar code and
/// `simd_active` records it).
fn simd_vs_scalar(iters: usize) -> Vec<Value> {
    let active = simd::simd_active();
    println!("== explicit SIMD layer vs fixed-8-lane scalar fallback (avx2 active: {active}) ==");
    let n = 8usize;
    let g = CommGraph::build(GraphKind::Ring, n).unwrap();
    let mut t = Table::new(&["kernel", "P", "scalar", "simd", "speedup"]);
    let mut cells = Vec::new();
    for p in [1usize << 16, 1 << 18, 1 << 20, 1 << 22] {
        // Big vectors get fewer iterations to keep the section bounded.
        let kernel_iters = if p >= 1 << 21 { (iters / 3).max(3) } else { iters };

        // -- axpy ------------------------------------------------------
        let src = flat(p, 4);
        let mut out = flat(p, 5);
        let mut time_axpy = |scalar: bool| {
            simd::force_scalar(scalar);
            let tm = bench(2, kernel_iters, || {
                simd::axpy(&mut out, &src, 1.000_001);
                std::hint::black_box(&mut out);
            });
            simd::force_scalar(false);
            tm
        };
        let axpy_scalar = time_axpy(true);
        let axpy_simd = time_axpy(false);

        // -- fused mix_step, 1 thread ---------------------------------
        let reps0 = replicas(n, p, 6);
        let gs = ReplicaMatrix::broadcast(n, &flat(p, 7));
        let time_mix = |scalar: bool| {
            simd::force_scalar(scalar);
            let mut engine = GossipEngine::new();
            let mut reps = reps0.clone();
            let mut states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, 0.9, 0.0)).collect();
            let tm = bench(1, kernel_iters, || {
                engine.mix_step(&g, &mut reps, &gs, &mut states, 0.01);
            });
            simd::force_scalar(false);
            tm
        };
        let mix_scalar = time_mix(true);
        let mix_simd = time_mix(false);

        // -- sum-of-squares reduction ---------------------------------
        let data = flat(p, 8);
        let time_reduce = |scalar: bool| {
            simd::force_scalar(scalar);
            let tm = bench(2, kernel_iters, || {
                std::hint::black_box(simd::sumsq_f64(&data));
            });
            simd::force_scalar(false);
            tm
        };
        let red_scalar = time_reduce(true);
        let red_simd = time_reduce(false);

        for (kernel, ts, tv) in [
            ("axpy", axpy_scalar, axpy_simd),
            ("mix_step", mix_scalar, mix_simd),
            ("sumsq_f64", red_scalar, red_simd),
        ] {
            let (s, v) = (ts.median.as_secs_f64(), tv.median.as_secs_f64());
            t.row(vec![
                kernel.into(),
                p.to_string(),
                fmt_duration(ts.median),
                fmt_duration(tv.median),
                format!("{:.2}x", s / v),
            ]);
            cells.push(Value::obj(vec![
                ("kernel", Value::Str(kernel.into())),
                ("p", Value::Num(p as f64)),
                ("scalar_median_s", Value::Num(s)),
                ("simd_median_s", Value::Num(v)),
                ("simd_speedup", Value::Num(s / v)),
                ("simd_active", Value::Bool(active)),
                ("iters", Value::Num(kernel_iters as f64)),
            ]));
        }
    }
    println!("{}", t.render());
    println!("(both paths are bit-identical — asserted in rust/tests/exec_determinism.rs)");
    cells
}

/// The overlapped bucketed pipeline against the phase-ordered
/// iteration it replaces. Both run the SAME per-row synthetic local
/// step (a fixed number of multiply-add passes, standing in for the
/// forward/backward compute of a real local step) and the SAME mix —
/// the phased variant runs them as two sequential phases on one engine,
/// the pipelined variant threads the producer through
/// `mix_overlapped`/`publish_overlapped` so bucket consumers start as
/// soon as their row frontier retires. Outputs are bit-identical
/// (asserted in `rust/tests/exec_determinism.rs`), so the ratio is pure
/// overlap.
fn pipeline_vs_phased(iters: usize) -> Vec<Value> {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("== overlapped pipeline vs phased iteration (host has {cores} cores) ==");

    // A deterministic stand-in for the local step: four fused
    // multiply-add passes over the row. Heavy enough that there is real
    // compute to hide the mix behind, cheap enough to sweep.
    fn local_work(w: usize, row: &mut [f32]) {
        for pass in 0..4u32 {
            let c = 1e-6 * (w as f32 + 1.0) * (pass as f32 + 1.0);
            for v in row.iter_mut() {
                *v = *v * 0.999_9 + c;
            }
        }
    }

    let graphs = [
        GraphKind::Ring,
        GraphKind::RingLattice { k: 3 },
        GraphKind::Exponential,
        GraphKind::Complete,
    ];
    let (n, p) = (16usize, 262_144usize);
    let thread_counts = [2usize, 4, 8];
    let bucket_kbs = [64usize, 256, 1024];

    let mut t = Table::new(&[
        "graph", "threads", "bucket_kb", "phased", "pipelined", "overlap gain",
    ]);
    let mut cells = Vec::new();
    for kind in graphs {
        let g = CommGraph::build(kind, n).unwrap();
        let src = replicas(n, p, 9);
        for threads in thread_counts {
            // -- phased baseline: local phase, then mix phase ---------
            let mut phased_engine = GossipEngine::with_threads(threads);
            let mut phased_reps = src.clone();
            let t_phased = bench(1, iters, || {
                for w in 0..n {
                    local_work(w, phased_reps.row_mut(w));
                }
                phased_engine.mix(&g, &mut phased_reps);
            });
            let phased_s = t_phased.median.as_secs_f64();

            for bucket_kb in bucket_kbs {
                // -- overlapped: producer steps rows while bucket
                //    consumers mix behind the retired frontier --------
                let mut engine = GossipEngine::with_threads(threads);
                engine.set_bucket_kb(bucket_kb);
                let mut reps = src.clone();
                let t_piped = bench(1, iters, || {
                    engine
                        .mix_overlapped(&g, &mut reps, None, |w, row| {
                            local_work(w, row);
                            Ok(())
                        })
                        .unwrap();
                    engine.publish_overlapped(&mut reps);
                });
                let piped_s = t_piped.median.as_secs_f64();
                t.row(vec![
                    kind.to_string(),
                    threads.to_string(),
                    bucket_kb.to_string(),
                    fmt_duration(t_phased.median),
                    fmt_duration(t_piped.median),
                    format!("{:.2}x", phased_s / piped_s),
                ]);
                cells.push(Value::obj(vec![
                    ("graph", Value::Str(kind.to_string())),
                    ("n", Value::Num(n as f64)),
                    ("p", Value::Num(p as f64)),
                    ("threads", Value::Num(threads as f64)),
                    ("bucket_kb", Value::Num(bucket_kb as f64)),
                    ("phased_median_s", Value::Num(phased_s)),
                    ("pipelined_median_s", Value::Num(piped_s)),
                    ("overlap_speedup", Value::Num(phased_s / piped_s)),
                    ("iters", Value::Num(iters as f64)),
                ]));
            }
        }
    }
    println!("{}", t.render());
    println!(
        "(same per-row local step + same mix on both sides; pipelined output\n\
         is bit-identical to phased, so overlap gain is pure wall-clock)"
    );
    cells
}

/// The bounded-staleness mixing path against the live-row mix it
/// shadows. Each stale round pays the full fault-plane bookkeeping —
/// ingest every delivered row into the per-edge buffer (ages tick on
/// the dropped ones), then renormalize over the fresh-enough peers —
/// under seeded drop weather from a [`FaultPlan`]. At `drop_prob = 0`
/// the stale path is bit-identical to `mix` (asserted in
/// `rust/tests/fault_injection.rs`), so that column is pure overhead.
fn stale_vs_fresh(iters: usize) -> Vec<Value> {
    println!("== bounded-staleness mixing vs live-row mix (seeded drop weather) ==");
    let (n, p) = (16usize, 262_144usize);
    let bound = 2usize;
    let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
    let src = replicas(n, p, 10);
    let mut t = Table::new(&["drop_prob", "threads", "fresh mix", "stale mix", "overhead"]);
    let mut cells = Vec::new();
    for drop_prob in [0.0f64, 0.1, 0.3] {
        let mut plan = FaultPlan::quiet();
        plan.seed = 11;
        plan.drop_prob = drop_prob;
        for threads in [1usize, 4, 8] {
            let mut fresh_engine = GossipEngine::with_threads(threads);
            let mut fresh_reps = src.clone();
            let t_fresh = bench(1, iters, || {
                fresh_engine.mix(&g, &mut fresh_reps);
            });

            let mut engine = GossipEngine::with_threads(threads);
            let mut reps = src.clone();
            let mut round = 0usize;
            let t_stale = bench(1, iters, || {
                let r = round;
                round += 1;
                engine.ingest_stale(&g, &reps, |s, d| plan.delivered(0, r, s, d));
                engine.mix_stale(&g, &mut reps, None, bound);
            });

            let (fresh_s, stale_s) =
                (t_fresh.median.as_secs_f64(), t_stale.median.as_secs_f64());
            t.row(vec![
                format!("{drop_prob:.1}"),
                threads.to_string(),
                fmt_duration(t_fresh.median),
                fmt_duration(t_stale.median),
                format!("{:.2}x", stale_s / fresh_s),
            ]);
            cells.push(Value::obj(vec![
                ("graph", Value::Str(GraphKind::Exponential.to_string())),
                ("n", Value::Num(n as f64)),
                ("p", Value::Num(p as f64)),
                ("drop_prob", Value::Num(drop_prob)),
                ("staleness_bound", Value::Num(bound as f64)),
                ("threads", Value::Num(threads as f64)),
                ("fresh_median_s", Value::Num(fresh_s)),
                ("stale_median_s", Value::Num(stale_s)),
                ("stale_over_fresh", Value::Num(stale_s / fresh_s)),
                ("iters", Value::Num(iters as f64)),
            ]));
        }
    }
    println!("{}", t.render());
    println!(
        "(overhead = ingest + buffered renormalizing mix vs the live-row mix;\n\
         at drop_prob 0.0 the outputs are bit-identical)"
    );
    cells
}

/// The compressed exchange paths against the dense f32 mix on one
/// paper-shaped cell. Local kernel wall-clock (the codec round-trips
/// per tile — *more* CPU work than dense) next to the modeled Summit
/// wire cost per round (the bytes the codec removes from the network) —
/// the trade the compression subsystem exists to make. Outputs of the
/// f32 row are bit-identical to `mix`; the lossy rows are quantized by
/// construction, so only wall-clock and modeled cost are compared.
fn compressed_vs_dense(iters: usize) -> Vec<Value> {
    println!("== compressed vs dense exchange (local kernel + modeled Summit wire) ==");
    let (n, p) = (16usize, 262_144usize);
    let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
    let net = SimNet::new(ClusterSpec::summit());
    let src = replicas(n, p, 12);
    let k = p / 16; // top-k keeps 1/16 of the coordinates per round
    let mut t = Table::new(&[
        "path", "threads", "median/round", "wire bytes/node", "wire time (ms)",
    ]);
    let mut cells = Vec::new();
    for threads in [1usize, 4, 8] {
        // Dense f32 baseline.
        let mut dense_engine = GossipEngine::with_threads(threads);
        let mut dense_reps = src.clone();
        let t_dense = bench(1, iters, || {
            dense_engine.mix(&g, &mut dense_reps);
        });
        let dense_s = t_dense.median.as_secs_f64();

        // Codec rounds + the sparse error-feedback path. Message sizes
        // follow the strategy layer's accounting: dense codec rounds
        // ship bytes_per_value·p per edge, top-k ships k·(4 + payload).
        let topk_msg = k as u64 * (4 + Codec::Bf16.bytes_per_value());
        let paths: [(&str, u64); 4] = [
            ("dense f32", 4 * p as u64),
            ("bf16", Codec::Bf16.bytes_per_value() * p as u64),
            ("f16", Codec::F16.bytes_per_value() * p as u64),
            ("topk bf16 (k=p/16)", topk_msg),
        ];
        for (name, bytes_per_msg) in paths {
            let tm = match name {
                "dense f32" => t_dense,
                "bf16" | "f16" => {
                    let codec = if name == "bf16" { Codec::Bf16 } else { Codec::F16 };
                    let mut engine = GossipEngine::with_threads(threads);
                    let mut reps = src.clone();
                    bench(1, iters, || {
                        engine.mix_codec(&g, &mut reps, codec);
                    })
                }
                _ => {
                    let mut engine = GossipEngine::with_threads(threads);
                    let mut reps = src.clone();
                    let mut residuals = ReplicaMatrix::zeros(n, p);
                    let mut messages = ReplicaMatrix::zeros(n, p);
                    bench(1, iters, || {
                        for w in 0..n {
                            sparsify_row(
                                reps.row(w),
                                residuals.row_mut(w),
                                messages.row_mut(w),
                                k,
                            );
                        }
                        engine.mix_from(&g, &mut reps, &messages, Codec::Bf16);
                    })
                }
            };
            let wire = net.gossip_round_bytes(&g, bytes_per_msg);
            let local_s = tm.median.as_secs_f64();
            t.row(vec![
                name.into(),
                threads.to_string(),
                fmt_duration(tm.median),
                (bytes_per_msg * g.degree() as u64).to_string(),
                format!("{:.3}", wire.time_s * 1e3),
            ]);
            cells.push(Value::obj(vec![
                ("path", Value::Str(name.into())),
                ("graph", Value::Str(GraphKind::Exponential.to_string())),
                ("n", Value::Num(n as f64)),
                ("p", Value::Num(p as f64)),
                ("threads", Value::Num(threads as f64)),
                ("local_median_s", Value::Num(local_s)),
                ("local_vs_dense", Value::Num(local_s / dense_s)),
                ("bytes_per_msg", Value::Num(bytes_per_msg as f64)),
                (
                    "wire_bytes_per_node",
                    Value::Num((bytes_per_msg * g.degree() as u64) as f64),
                ),
                ("wire_time_s", Value::Num(wire.time_s)),
                ("wire_total_bytes", Value::Num(wire.total_bytes as f64)),
                ("iters", Value::Num(iters as f64)),
            ]));
        }
    }
    println!("{}", t.render());
    println!(
        "(the codec rounds spend extra CPU on per-tile round-trips to cut wire\n\
         bytes 2x, top-k ~10x — wire time from the SimNet Summit α–β model)"
    );
    cells
}

fn write_bench_json(
    sweep: Vec<Value>,
    pool: Vec<Value>,
    reduce: Vec<Value>,
    simd: Vec<Value>,
    pipeline: Vec<Value>,
    stale: Vec<Value>,
    compressed: Vec<Value>,
) {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let doc = Value::obj(vec![
        ("status", Value::Str("measured".into())),
        ("bench", Value::Str("gossip_bench".into())),
        ("host_cores", Value::Num(cores as f64)),
        ("sweep", Value::Arr(sweep)),
        ("pool_vs_scoped", Value::Arr(pool)),
        ("reduce_vs_serial_variance", Value::Arr(reduce)),
        ("simd_vs_scalar", Value::Arr(simd)),
        ("pipeline_vs_phased", Value::Arr(pipeline)),
        ("stale_vs_fresh", Value::Arr(stale)),
        ("compressed_vs_dense", Value::Arr(compressed)),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_gossip.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}

#[cfg(feature = "pjrt")]
fn hlo_section(iters: usize) {
    use ada_dist::runtime::{GossipKernel, PjRtRuntime};
    // HLO kernel path (requires artifacts).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("gossip/manifest.json").exists() {
        println!("== gossip mixing: L1 Pallas kernel via PJRT ==");
        let rt = PjRtRuntime::cpu(&dir).expect("pjrt");
        let mut t = Table::new(&["graph", "n", "P", "median/round", "vs native"]);
        for (n, p) in [(8, 2762), (8, 72000), (32, 72000)] {
            let Ok(kernel) = GossipKernel::load(&rt, n, p) else {
                continue;
            };
            for kind in [GraphKind::Ring, GraphKind::Complete] {
                let g = CommGraph::build(kind, n).unwrap();
                let mut reps = replicas(n, p, 2).to_vecs();
                let tm = bench(2, (iters / 3).max(3), || {
                    kernel.mix(&g, &mut reps).unwrap();
                });
                let mut engine = GossipEngine::new();
                let mut reps2 = replicas(n, p, 2);
                let tn = bench(2, iters, || {
                    engine.mix(&g, &mut reps2);
                });
                t.row(vec![
                    kind.to_string(),
                    n.to_string(),
                    p.to_string(),
                    fmt_duration(tm.median),
                    format!("{:.1}x slower", tm.median.as_secs_f64() / tn.median.as_secs_f64()),
                ]);
            }
        }
        println!("{}", t.render());
        println!(
            "(the HLO path pays PJRT dispatch + H2D/D2H copies per call; on real TPU\n\
             hardware the same kernel runs from VMEM — see EXPERIMENTS.md §Perf)"
        );
    } else {
        println!("(artifacts missing — skipping HLO kernel path; run `make artifacts`)");
    }
}
