//! Gossip mixing engine benchmarks — the L3 hot path.
//!
//! Three execution paths over identical inputs:
//!   * `native`   — the sparse row-wise engine (production path)
//!   * `dense`    — the O(n²P) dense reference (baseline)
//!   * `hlo`      — the L1 Pallas kernel via PJRT (when artifacts exist)
//!
//! Prints per-round latency and effective bandwidth (bytes touched/s).
//! Run: `cargo bench --bench gossip_bench`.

use ada_dist::gossip::{mix_dense_reference, GossipEngine};
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::runtime::{GossipKernel, PjRtRuntime};
use ada_dist::util::bench::{bench, env_usize, fmt_duration, Table};
use ada_dist::util::rng::Rng;

fn replicas(n: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect()
}

fn main() {
    let iters = env_usize("ADA_BENCH_ITERS", 30);
    println!("== gossip mixing: native vs dense reference ==");
    let mut t = Table::new(&["graph", "n", "P", "path", "median/round", "GB/s"]);
    for (n, p) in [(8, 2762), (16, 72000), (32, 72000), (64, 1_000_000)] {
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::Complete] {
            let g = CommGraph::build(kind, n).unwrap();
            // Bytes read+written per round on the sparse path.
            let touched = ((g.degree() + 2) * n * p * 4) as f64;
            let src = replicas(n, p, 1);
            let mut engine = GossipEngine::new();
            let mut reps = src.clone();
            let tm = bench(2, iters, || {
                engine.mix(&g, &mut reps);
            });
            t.row(vec![
                kind.to_string(),
                n.to_string(),
                p.to_string(),
                "native".into(),
                fmt_duration(tm.median),
                format!("{:.2}", touched / tm.median.as_secs_f64() / 1e9),
            ]);
            if p <= 100_000 {
                let tm = bench(1, (iters / 3).max(3), || {
                    std::hint::black_box(mix_dense_reference(&g, &src));
                });
                t.row(vec![
                    kind.to_string(),
                    n.to_string(),
                    p.to_string(),
                    "dense-ref".into(),
                    fmt_duration(tm.median),
                    format!("{:.2}", touched / tm.median.as_secs_f64() / 1e9),
                ]);
            }
        }
    }
    println!("{}", t.render());

    // HLO kernel path (requires artifacts).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("gossip/manifest.json").exists() {
        println!("== gossip mixing: L1 Pallas kernel via PJRT ==");
        let rt = PjRtRuntime::cpu(&dir).expect("pjrt");
        let mut t = Table::new(&["graph", "n", "P", "median/round", "vs native"]);
        for (n, p) in [(8, 2762), (8, 72000), (32, 72000)] {
            let Ok(kernel) = GossipKernel::load(&rt, n, p) else {
                continue;
            };
            for kind in [GraphKind::Ring, GraphKind::Complete] {
                let g = CommGraph::build(kind, n).unwrap();
                let mut reps = replicas(n, p, 2);
                let tm = bench(2, (iters / 3).max(3), || {
                    kernel.mix(&g, &mut reps).unwrap();
                });
                let mut engine = GossipEngine::new();
                let mut reps2 = replicas(n, p, 2);
                let tn = bench(2, iters, || {
                    engine.mix(&g, &mut reps2);
                });
                t.row(vec![
                    kind.to_string(),
                    n.to_string(),
                    p.to_string(),
                    fmt_duration(tm.median),
                    format!("{:.1}x slower", tm.median.as_secs_f64() / tn.median.as_secs_f64()),
                ]);
            }
        }
        println!("{}", t.render());
        println!(
            "(the HLO path pays PJRT dispatch + H2D/D2H copies per call; on real TPU\n\
             hardware the same kernel runs from VMEM — see EXPERIMENTS.md §Perf)"
        );
    } else {
        println!("(artifacts missing — skipping HLO kernel path; run `make artifacts`)");
    }
}
