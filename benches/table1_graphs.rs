//! Table 1 — characteristics of the representative communication graphs
//! — regenerated from the graph substrate, plus build/spectral micro-
//! benchmarks of the graph layer (the coordinator rebuilds lattices on
//! every Ada decay step, so construction cost matters).
//!
//! Run: `cargo bench --bench table1_graphs` (ADA_BENCH_FULL=1 adds n=1008).

use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::util::bench::{bench, env_flag, fmt_duration, Table};

fn paper_degree(kind: GraphKind, n: usize) -> String {
    match kind {
        GraphKind::Ring => "2".into(),
        GraphKind::Torus => "4".into(),
        GraphKind::RingLattice { k } => format!("2k={}", 2 * k),
        GraphKind::AdaLattice { k } => format!("k={k}"),
        GraphKind::Exponential => {
            format!("⌊log2(n-1)⌋+1={}", ((n - 1) as f64).log2().floor() as usize + 1)
        }
        GraphKind::Complete => format!("n-1={}", n - 1),
        GraphKind::Hypercube => format!("log2(n)={}", n.trailing_zeros()),
        GraphKind::RandomRegular { d, .. } => format!("d={d}"),
    }
}

fn paper_edges(kind: GraphKind, n: usize) -> String {
    match kind {
        GraphKind::Ring => format!("n={n}"),
        GraphKind::Torus => format!("2n={}", 2 * n),
        GraphKind::RingLattice { k } => format!("kn={}", k * n),
        GraphKind::AdaLattice { k } => format!("≈kn/2={}", k * n / 2),
        GraphKind::Exponential => format!(
            "n(⌊log2(n-1)⌋+1)={}",
            n * (((n - 1) as f64).log2().floor() as usize + 1)
        ),
        GraphKind::Complete => format!("n(n-1)/2={}", n * (n - 1) / 2),
        GraphKind::Hypercube => format!("n·log2(n)/2={}", n * n.trailing_zeros() as usize / 2),
        GraphKind::RandomRegular { d, .. } => format!("dn/2={}", d * n / 2),
    }
}

fn main() {
    let mut ns = vec![12, 24, 48, 96];
    if env_flag("ADA_BENCH_FULL") {
        ns.push(1008);
    }
    for &n in &ns {
        println!("== Table 1 @ n = {n} ==");
        let mut t = Table::new(&[
            "graph", "degree", "paper", "edges", "paper", "directed", "gap(1-σ2)",
        ]);
        for kind in [
            GraphKind::Ring,
            GraphKind::Torus,
            GraphKind::RingLattice { k: 3 },
            GraphKind::Exponential,
            GraphKind::Complete,
        ] {
            let g = match CommGraph::build(kind, n) {
                Ok(g) => g,
                Err(e) => {
                    println!("  {kind}: {e}");
                    continue;
                }
            };
            t.row(vec![
                kind.to_string(),
                g.degree().to_string(),
                paper_degree(kind, n),
                g.edge_count().to_string(),
                paper_edges(kind, n),
                g.is_directed().to_string(),
                format!("{:.6}", g.spectral_gap()),
            ]);
        }
        println!("{}", t.render());
    }

    // Micro-benchmarks: construction + spectral gap (Ada's per-epoch cost).
    println!("== graph-layer micro-benchmarks (n = 96) ==");
    let mut t = Table::new(&["operation", "median", "min"]);
    for kind in [
        GraphKind::Ring,
        GraphKind::Torus,
        GraphKind::Exponential,
        GraphKind::AdaLattice { k: 10 },
        GraphKind::Complete,
    ] {
        let timing = bench(3, 20, || {
            std::hint::black_box(CommGraph::build(kind, 96).unwrap());
        });
        t.row(vec![
            format!("build {kind}"),
            fmt_duration(timing.median),
            fmt_duration(timing.min),
        ]);
    }
    let g = CommGraph::build(GraphKind::Torus, 96).unwrap();
    let timing = bench(1, 5, || {
        std::hint::black_box(g.spectral_gap());
    });
    t.row(vec![
        "spectral_gap torus@96".into(),
        fmt_duration(timing.median),
        fmt_duration(timing.min),
    ]);
    println!("{}", t.render());
}
