//! Figure 7 + Table 4 — Ada vs centralized / static decentralized
//! baselines on all four applications, plus the 1008-GPU scale analysis
//! of Fig 7(d).
//!
//! Paper shape to reproduce: `D_adaptive` (Ada) converges at least as
//! fast as the best baseline and lands within noise of `C_complete`'s
//! final accuracy, while `D_ring`/`D_torus` trail (catastrophically at
//! the largest scales); Ada's communication cost sits far below
//! `D_complete`'s and decays toward ring cost as `k` shrinks.
//!
//! Fig 7(d) ran on 1008 GPUs — infeasible wall-clock here, but the
//! quantities the argument rests on (graph degree, spectral gap, Summit
//! comm cost) are *exact* at n = 1008 and printed below.
//!
//! Run: `cargo bench --bench fig7_ada` (ADA_BENCH_FULL=1: 64 workers,
//! all four apps, more epochs).

use ada_dist::coordinator::SgdFlavor;
use ada_dist::dbench::{format_table, run_experiment, ExperimentSpec};
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::simnet::{ClusterSpec, SimNet};
use ada_dist::topology::{AdaSchedule, TopologyPolicy};
use ada_dist::util::bench::{env_flag, env_usize, Table};

fn main() {
    let full = env_flag("ADA_BENCH_FULL");
    let workers = env_usize("ADA_BENCH_SCALE", if full { 64 } else { 16 });
    let epochs = env_usize("ADA_BENCH_EPOCHS", if full { 14 } else { 8 });
    // Table 4: (k0, γk) — scaled from (10, 0.02)@96 GPUs to this run's
    // scale and epoch budget (k must traverse dense → sparse in-run).
    let k0 = (workers * 10 / 96).max(workers / 2).min(workers - 1).max(4);
    let gamma_k = k0 as f64 / (epochs as f64 * 0.75);
    println!("== Table 4: Ada tuning parameters ==");
    println!(
        "paper:   k0=10, γk=0.02 @ 96 GPUs (300 epochs); k0=112, γk=1 @ 1008 GPUs (90 epochs)"
    );
    println!("this run: k0={k0}, γk={gamma_k:.2} @ {workers} workers ({epochs} epochs)\n");

    let mut apps = ExperimentSpec::four_applications();
    if !full {
        apps.truncate(2);
    }
    for mut spec in apps {
        spec.scales = vec![workers];
        spec.epochs = epochs;
        spec.metrics_every = 2;
        spec.flavors = vec![
            SgdFlavor::CentralizedComplete,
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedTorus,
            SgdFlavor::Ada { k0, gamma_k },
            SgdFlavor::OnePeer,
        ];
        let t0 = std::time::Instant::now();
        let cells = run_experiment(&spec).expect("sweep");
        println!(
            "{}",
            format_table(
                &format!("Fig 7: {} @ {workers} workers ({:.1?})", spec.name, t0.elapsed()),
                &cells
            )
        );
    }

    // --- Fig 7(d) scale analysis at n = 1008 (exact) ------------------
    println!("== Fig 7(d) scale analysis @ n = 1008, ResNet50 (25.56M params) ==");
    let n = 1008;
    let p = 25_560_000;
    let net = SimNet::new(ClusterSpec::summit());
    let ada = AdaSchedule::new(n, 112, 1.0); // Table 4's exact values
    let mut t = Table::new(&["topology", "degree", "spectral gap", "round cost (ms)"]);
    for kind in [GraphKind::Ring, GraphKind::Torus, GraphKind::Exponential] {
        let g = CommGraph::build(kind, n).unwrap();
        t.row(vec![
            kind.to_string(),
            g.degree().to_string(),
            format!("{:.6}", g.spectral_gap()),
            format!("{:.2}", net.gossip_round(&g, p).time_s * 1e3),
        ]);
    }
    for epoch in [0usize, 30, 60, 90] {
        let g = ada.graph_for_epoch(epoch).unwrap();
        t.row(vec![
            format!("ada @ epoch {epoch} (k={})", ada.k_for_epoch(epoch)),
            g.degree().to_string(),
            format!("{:.6}", g.spectral_gap()),
            format!("{:.2}", net.gossip_round(&g, p).time_s * 1e3),
        ]);
    }
    let ar = net.allreduce(n, p);
    t.row(vec![
        "C_complete (allreduce)".into(),
        (n - 1).to_string(),
        "-".into(),
        format!("{:.2}", ar.time_s * 1e3),
    ]);
    println!("{}", t.render());

    // Total comm budget over the 90-epoch ResNet50 recipe.
    let iters_per_epoch = 1_281_167 / 16 / n; // ImageNet, batch 16/GPU
    let ada_bytes = ada.comm_bytes_per_node(90, iters_per_epoch, p).unwrap();
    let ring = CommGraph::build(GraphKind::Ring, n).unwrap();
    let ring_bytes = ring.bytes_sent_per_node(p) * (90 * iters_per_epoch) as u64;
    let complete = CommGraph::build(GraphKind::Complete, n).unwrap();
    let complete_bytes = complete.bytes_sent_per_node(p) * (90 * iters_per_epoch) as u64;
    println!(
        "90-epoch comm per node — ring: {:.1} TB, Ada: {:.1} TB, D_complete: {:.1} TB\n\
         (Ada @ {:.1}% of D_complete; paper's claim: complete-graph accuracy at a\n\
         fraction of its communication)",
        ring_bytes as f64 / 1e12,
        ada_bytes as f64 / 1e12,
        complete_bytes as f64 / 1e12,
        100.0 * ada_bytes as f64 / complete_bytes as f64,
    );
}
