//! Ablations over the design choices DESIGN.md calls out:
//!
//!  A. **LARS in the decentralized setting** — the paper's §4.2 future
//!     work: does layer-wise adaptive rate scaling recover large-batch
//!     accuracy for Ada and the static graphs?
//!  B. **Shard heterogeneity (Dirichlet α)** — the mechanism knob behind
//!     graph sensitivity: with iid shards, graphs barely matter; the
//!     skewier the shards, the bigger the ring↔complete gap.
//!  C. **Metrics cadence** — DBench's every-iteration variance capture
//!     costs O(nP); what does it cost end-to-end?
//!
//! Run: `cargo bench --bench ablation_bench`.

use ada_dist::coordinator::surrogate::SoftmaxRegression;
use ada_dist::coordinator::{LarsWrapped, LrPolicy, SgdFlavor, TrainConfig, Trainer};
use ada_dist::data::{ShardStrategy, SyntheticClassification};
use ada_dist::optim::LrSchedule;
use ada_dist::util::bench::{env_usize, Table};

fn base_config(n: usize, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::quick(n, epochs);
    cfg.lr = LrPolicy::Fixed {
        schedule: LrSchedule::Constant { lr: 0.05 },
    };
    cfg
}

fn main() {
    let n = env_usize("ADA_BENCH_SCALE", 16);
    let epochs = env_usize("ADA_BENCH_EPOCHS", 6);
    let data = SyntheticClassification::generate(4096, 32, 10, 2.5, 42);
    let k0 = n - 1;
    let gamma_k = k0 as f64 / (epochs as f64 * 0.75);

    // --- A: LARS ------------------------------------------------------
    println!("== ablation A: LARS in decentralized training (§4.2 future work) ==");
    let mut t = Table::new(&["flavor", "optimizer", "final acc", "diverged"]);
    for flavor in [
        SgdFlavor::Ada { k0, gamma_k },
        SgdFlavor::DecentralizedRing,
        SgdFlavor::DecentralizedComplete,
    ] {
        // Plain momentum SGD at a deliberately aggressive LR (the
        // large-batch regime the paper worries about at 1008 GPUs).
        let mut cfg = base_config(n, epochs);
        cfg.lr = LrPolicy::Fixed {
            schedule: LrSchedule::Constant { lr: 3.0 },
        };
        let mut plain = SoftmaxRegression::new(32, 10, 16, 64, n, 0.9);
        let (_, s) = Trainer::new(&mut plain, cfg.clone())
            .run(&data, &flavor)
            .expect("plain");
        t.row(vec![
            s.flavor.clone(),
            "sgd+momentum lr=3.0".into(),
            format!("{:.4}", s.final_eval.metric),
            s.diverged.to_string(),
        ]);
        // LARS at the same nominal LR: trust ratios normalize per layer.
        let mut cfg = base_config(n, epochs);
        cfg.lr = LrPolicy::Fixed {
            schedule: LrSchedule::Constant { lr: 3.0 },
        };
        let mut lars = LarsWrapped::new(
            SoftmaxRegression::new(32, 10, 16, 64, n, 0.0),
            n,
            0.05,
            0.9,
            1e-4,
        );
        let (_, s) = Trainer::new(&mut lars, cfg).run(&data, &flavor).expect("lars");
        t.row(vec![
            s.flavor.clone(),
            "LARS lr=3.0".into(),
            format!("{:.4}", s.final_eval.metric),
            s.diverged.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "reading: at this (convex, miniature) scale LARS is neutral-to-positive\n\
         for the densest averaging (D_complete — the large-batch regime LARS\n\
         was designed for) and neutral for sparse graphs; the paper proposes\n\
         exactly this experiment at 1008 GPUs as future work.\n"
    );

    // --- B: shard heterogeneity ---------------------------------------
    println!("== ablation B: Dirichlet α vs graph sensitivity ==");
    let mut t = Table::new(&["alpha", "D_ring", "D_complete", "gap"]);
    for alpha in [10.0, 1.0, 0.3, 0.1] {
        let acc = |flavor: &SgdFlavor| {
            let mut cfg = base_config(n, 3);
            cfg.shard = ShardStrategy::LabelSkew { alpha };
            let mut model = SoftmaxRegression::new(32, 10, 16, 64, n, 0.9);
            Trainer::new(&mut model, cfg)
                .run(&data, flavor)
                .expect("run")
                .1
                .final_eval
                .metric
        };
        let ring = acc(&SgdFlavor::DecentralizedRing);
        let complete = acc(&SgdFlavor::DecentralizedComplete);
        t.row(vec![
            format!("{alpha}"),
            format!("{ring:.4}"),
            format!("{complete:.4}"),
            format!("{:+.4}", complete - ring),
        ]);
    }
    println!("{}", t.render());
    println!(
        "expected: the complete−ring gap widens as α shrinks (shards grow\n\
         non-iid); at extreme skew both collapse within the epoch budget —\n\
         the unconvergence regime of the paper's large-scale cells.\n"
    );

    // --- C: metrics cadence --------------------------------------------
    println!("== ablation C: DBench metrics-capture overhead ==");
    let mut t = Table::new(&["metrics_every", "wall time", "iters"]);
    let big = SyntheticClassification::generate(8192, 64, 20, 2.0, 9);
    for every in [1usize, 4, 16, 0] {
        let mut cfg = base_config(32, 4);
        cfg.metrics_every = every;
        let mut model = ada_dist::coordinator::surrogate::MlpClassifier::new(
            64, 128, 20, 16, 64, 32, 0.9,
        );
        let t0 = std::time::Instant::now();
        let (rec, _) = Trainer::new(&mut model, cfg)
            .run(&big, &SgdFlavor::DecentralizedTorus)
            .expect("run");
        t.row(vec![
            if every == 0 { "off".into() } else { every.to_string() },
            format!("{:.1?}", t0.elapsed()),
            rec.records().len().to_string(),
        ]);
    }
    println!("{}", t.render());
}
