//! Figure 2 — model accuracy vs training scale for `D_ring` (left) and
//! `D_complete` (right).
//!
//! Paper shape to reproduce: for a fixed SGD implementation, final
//! accuracy *decreases* as the scale grows, and the drop is much larger
//! for the sparse ring (2%–23.4% in the paper) than for the complete
//! graph (1.4%–5%).
//!
//! Run: `cargo bench --bench fig2_scale_accuracy`
//! (quick preset: scales {8,16,32}; ADA_BENCH_FULL=1 extends the scale
//! axis to {8,16,32,64,128,256} and adds epochs). The sweep runs on the
//! parallel execution path by default — `ADA_BENCH_THREADS` (0 = all
//! cores) and `ADA_BENCH_FUSED=1` control the engine, and results are
//! bit-identical for every thread count (see `crate::exec`).

use ada_dist::coordinator::SgdFlavor;
use ada_dist::dbench::{run_cell, ExperimentSpec};
use ada_dist::util::bench::{env_flag, env_usize, Table};

fn main() {
    let full = env_flag("ADA_BENCH_FULL");
    let scales: Vec<usize> = if full {
        vec![8, 16, 32, 64, 128, 256]
    } else {
        vec![8, 16, 32]
    };
    let mut spec = ExperimentSpec::resnet50_analog();
    spec.epochs = env_usize("ADA_BENCH_EPOCHS", if full { 12 } else { 6 });
    spec.metrics_every = 4;
    // Default to the pooled parallel engine so the O(n·P) gossip,
    // variance-capture and mean-eval passes fan out — without it the
    // n=128/256 cells are serial-pass bound.
    spec.threads = env_usize("ADA_BENCH_THREADS", 0);
    spec.fused = env_flag("ADA_BENCH_FUSED");

    println!(
        "== Fig 2: accuracy vs scale (workload {}, {} epochs, threads={}, fused={}) ==",
        spec.workload.name(),
        spec.epochs,
        if spec.threads == 0 { "auto".into() } else { spec.threads.to_string() },
        spec.fused
    );
    let mut t = Table::new(&["flavor", "scale", "final acc", "best acc", "drop vs n=8"]);
    for flavor in [SgdFlavor::DecentralizedRing, SgdFlavor::DecentralizedComplete] {
        let mut base: Option<f64> = None;
        for &scale in &scales {
            let t0 = std::time::Instant::now();
            let cell = run_cell(&spec, scale, &flavor).expect("cell");
            let acc = cell.summary.final_eval.metric;
            let best = cell
                .recorder
                .best_test_metric(true)
                .unwrap_or(acc);
            let drop = base.map(|b| format!("{:+.1}%", (acc - b) * 100.0));
            if base.is_none() {
                base = Some(acc);
            }
            t.row(vec![
                cell.flavor.clone(),
                scale.to_string(),
                format!("{acc:.4}"),
                format!("{best:.4}"),
                drop.unwrap_or_else(|| format!("(base, {:.1?})", t0.elapsed())),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "expected shape: accuracy falls with scale for both flavors, with the\n\
         ring losing more than the complete graph (paper: −2..−23.4% vs −1.4..−5%)."
    );
}
