//! Figure 2 — model accuracy vs training scale for `D_ring` (left) and
//! `D_complete` (right).
//!
//! Paper shape to reproduce: for a fixed SGD implementation, final
//! accuracy *decreases* as the scale grows, and the drop is much larger
//! for the sparse ring (2%–23.4% in the paper) than for the complete
//! graph (1.4%–5%).
//!
//! Run: `cargo bench --bench fig2_scale_accuracy`
//! (quick preset: scales {8,16,32}; ADA_BENCH_FULL=1 extends the scale
//! axis to {8,…,512,1024} and adds epochs). At the large scales the
//! synthetic dataset is grown so every shard keeps ≥~16 batches under
//! label skew, and `ADA_BENCH_MAX_ITERS` (default 25 in the full
//! preset, 0 = uncapped) bounds iterations per epoch so the small
//! scales don't pay thousand-iteration epochs on the grown dataset.
//! The sweep runs on the parallel execution path by default —
//! `ADA_BENCH_THREADS` (0 = all cores) and `ADA_BENCH_FUSED=1` control
//! the engine, and results are bit-identical for every thread count
//! (see `crate::exec`).

use ada_dist::coordinator::{SgdFlavor, Trainer};
use ada_dist::dbench::ExperimentSpec;
use ada_dist::util::bench::{env_flag, env_usize, Table};

fn main() {
    let full = env_flag("ADA_BENCH_FULL");
    let scales: Vec<usize> = if full {
        vec![8, 16, 32, 64, 128, 256, 512, 1024]
    } else {
        vec![8, 16, 32]
    };
    let mut spec = ExperimentSpec::resnet50_analog();
    spec.epochs = env_usize("ADA_BENCH_EPOCHS", if full { 12 } else { 6 });
    spec.metrics_every = 4;
    // Default to the pooled parallel engine so the O(n·P) gossip,
    // variance-capture and mean-eval passes fan out — without it the
    // n=128/256 cells are serial-pass bound.
    spec.threads = env_usize("ADA_BENCH_THREADS", 0);
    spec.fused = env_flag("ADA_BENCH_FUSED");
    // Scale-sweep support (ROADMAP: n=512–1024): one dataset sized for
    // the largest scale (~16 batches per shard past the test split,
    // never shrinking the preset), shared by every cell; the iteration
    // cap keeps epochs bounded at the small scales.
    if full {
        let max_scale = *scales.iter().max().expect("scales");
        spec.workload
            .ensure_examples(max_scale * spec.workload.batch_size() * 16 * 20 / 17);
    }
    spec.max_iters_per_epoch =
        match env_usize("ADA_BENCH_MAX_ITERS", if full { 25 } else { 0 }) {
            0 => None,
            m => Some(m),
        };

    println!(
        "== Fig 2: accuracy vs scale (workload {}, {} epochs, threads={}, fused={}) ==",
        spec.workload.name(),
        spec.epochs,
        if spec.threads == 0 { "auto".into() } else { spec.threads.to_string() },
        spec.fused
    );
    // Generate the (possibly grown) dataset exactly once; every cell
    // trains on it with identical init and sharding per scale — same
    // results as per-cell generation (the dataset is a pure function of
    // the seed), minus regenerating ~P·scale examples per cell.
    let dataset = spec.workload.dataset(spec.seed).expect("dataset");
    let mut t = Table::new(&["flavor", "scale", "final acc", "best acc", "drop vs n=8"]);
    for flavor in [SgdFlavor::DecentralizedRing, SgdFlavor::DecentralizedComplete] {
        let mut base: Option<f64> = None;
        for &scale in &scales {
            let t0 = std::time::Instant::now();
            let mut model = spec.workload.model(scale).expect("model");
            let mut trainer = Trainer::new(model.as_mut(), spec.train_config(scale));
            let (recorder, summary) =
                trainer.run(dataset.as_ref(), &flavor).expect("cell");
            let acc = summary.final_eval.metric;
            let best = recorder.best_test_metric(true).unwrap_or(acc);
            let drop = base.map(|b| format!("{:+.1}%", (acc - b) * 100.0));
            if base.is_none() {
                base = Some(acc);
            }
            t.row(vec![
                summary.flavor.clone(),
                scale.to_string(),
                format!("{acc:.4}"),
                format!("{best:.4}"),
                drop.unwrap_or_else(|| format!("(base, {:.1?})", t0.elapsed())),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "expected shape: accuracy falls with scale for both flavors, with the\n\
         ring losing more than the complete graph (paper: −2..−23.4% vs −1.4..−5%)."
    );
}
