//! Runtime benchmarks — the per-iteration budget of a training step.
//!
//! Sections:
//!   1. **coordinator throughput sweep** (always available): full
//!      n-worker iterations over the pure-Rust surrogate at
//!      threads × {split, fused} — how much of the iteration the
//!      multi-threaded engine and the fused gossip+SGD kernel recover.
//!   2. PJRT sections (pjrt builds with artifacts): executable
//!      load+compile time, `init`/`step`/`eval` latency per model, and
//!      coordinator overhead around the PJRT call.
//!
//! Run: `cargo bench --bench runtime_bench`
//! (PJRT sections additionally need `--features pjrt` + `make artifacts`).

use ada_dist::coordinator::surrogate::MlpClassifier;
use ada_dist::coordinator::{LrPolicy, SgdFlavor, TrainConfig, Trainer};
use ada_dist::data::SyntheticClassification;
use ada_dist::optim::LrSchedule;
use ada_dist::util::bench::{bench, env_usize, fmt_duration, Table};

fn main() {
    coordinator_sweep();
    #[cfg(feature = "pjrt")]
    pjrt_sections();
    #[cfg(not(feature = "pjrt"))]
    println!("(pure-std build — skipping PJRT sections; use --features pjrt)");
}

/// Full-iteration throughput of the n-worker coordinator on the
/// surrogate MLP: threads × execution-mode grid. The gossip/fused
/// engine is the only part that changes — gradients dominate at small
/// P, mixing dominates as P grows, which is exactly what the fused
/// kernel and the thread fan-out attack.
fn coordinator_sweep() {
    let n = env_usize("ADA_BENCH_SCALE", 8);
    let hidden = env_usize("ADA_BENCH_HIDDEN", 256);
    let reps = env_usize("ADA_BENCH_ITERS", 5).max(3);
    let data = SyntheticClassification::generate(2048, 64, 10, 2.5, 42);
    println!("== coordinator throughput: {n} workers, MLP(64→{hidden}→10) ==");
    let make_cfg = |threads: usize, fused: bool| {
        let mut cfg = TrainConfig::quick(n, 2);
        cfg.lr = LrPolicy::Fixed {
            schedule: LrSchedule::Constant { lr: 0.05 },
        };
        cfg.max_iters_per_epoch = Some(8);
        cfg.eval_every_epochs = 0;
        cfg.metrics_every = 0;
        cfg.threads = threads;
        cfg.fused = fused;
        cfg
    };
    // Untimed run to learn the actual iteration count (the per-epoch cap
    // of 8 only binds when every worker's shard has ≥ 8 batches).
    let iterations = {
        let mut model = MlpClassifier::new(64, hidden, 10, 16, 64, n, 0.9);
        let mut trainer = Trainer::new(&mut model, make_cfg(1, false));
        let (rec, _) = trainer.run(&data, &SgdFlavor::DecentralizedExponential).unwrap();
        rec.records().len() as f64
    };
    let mut t = Table::new(&["threads", "mode", "median/run", "iters/s"]);
    for threads in [1usize, 2, 4, 8] {
        for fused in [false, true] {
            let tm = bench(1, reps, || {
                let mut model = MlpClassifier::new(64, hidden, 10, 16, 64, n, 0.9);
                let mut trainer = Trainer::new(&mut model, make_cfg(threads, fused));
                std::hint::black_box(
                    trainer.run(&data, &SgdFlavor::DecentralizedExponential).unwrap(),
                );
            });
            t.row(vec![
                threads.to_string(),
                if fused { "fused" } else { "split" }.into(),
                fmt_duration(tm.median),
                format!("{:.1}", iterations / tm.median.as_secs_f64()),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "(split = local momentum step then gossip; fused = gradients then the\n\
         one-pass W·θ + momentum kernel. Outputs are bit-identical across the\n\
         threads column — see rust/tests/exec_determinism.rs)"
    );
}

#[cfg(feature = "pjrt")]
fn pjrt_sections() {
    use ada_dist::coordinator::{HloModel, LocalModel};
    use ada_dist::data::{Dataset, SyntheticLm};
    use ada_dist::runtime::PjRtRuntime;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("mlp/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return;
    }
    let iters = env_usize("ADA_BENCH_ITERS", 20);
    let rt = PjRtRuntime::cpu(&dir).expect("pjrt client");
    println!("platform: {}\n", rt.platform());

    println!("== artifact load + XLA compile ==");
    let mut t = Table::new(&["model", "load+compile (median)"]);
    for name in ["mlp", "cnn", "lstm", "transformer"] {
        let tm = bench(1, 3, || {
            std::hint::black_box(rt.load_model(name).unwrap());
        });
        t.row(vec![name.into(), fmt_duration(tm.median)]);
    }
    println!("{}", t.render());

    println!("== per-call latency (one worker-iteration = one `step`) ==");
    let mut t = Table::new(&["model", "P", "init", "step", "eval", "steps/s"]);
    for name in ["mlp", "cnn", "lstm", "transformer"] {
        let mut model = HloModel::new(rt.load_model(name).unwrap());
        let m = model.bundle().manifest.clone();
        let (bx, ex): (Box<dyn Dataset>, Box<dyn Dataset>) = match m.kind {
            ada_dist::runtime::ModelKind::Classification => (
                Box::new(SyntheticClassification::generate(
                    512, m.x_dim, m.num_outputs, 3.0, 1,
                )),
                Box::new(SyntheticClassification::generate(
                    512, m.x_dim, m.num_outputs, 3.0, 2,
                )),
            ),
            ada_dist::runtime::ModelKind::Lm => (
                Box::new(SyntheticLm::generate(512, m.x_dim, m.num_outputs, 2, 1)),
                Box::new(SyntheticLm::generate(512, m.x_dim, m.num_outputs, 2, 2)),
            ),
        };
        let train_batch = bx.batch(&(0..m.batch_size).collect::<Vec<_>>());
        let eval_batch = ex.batch(&(0..m.eval_batch_size).collect::<Vec<_>>());
        let mut params = model.init_params(0).unwrap();

        let t_init = bench(1, iters.min(10), || {
            std::hint::black_box(model.init_params(1).unwrap());
        });
        let t_step = bench(2, iters, || {
            model.local_step(0, &mut params, &train_batch, 0.01).unwrap();
        });
        let t_eval = bench(1, iters.min(10), || {
            std::hint::black_box(model.eval_sums(&params, &eval_batch).unwrap());
        });
        t.row(vec![
            name.into(),
            m.param_count.to_string(),
            fmt_duration(t_init.median),
            fmt_duration(t_step.median),
            fmt_duration(t_eval.median),
            format!("{:.0}", 1.0 / t_step.median.as_secs_f64()),
        ]);
    }
    println!("{}", t.render());

    println!("== coordinator overhead around the PJRT call ==");
    // Measure a full n-worker iteration and subtract n × step latency.
    let n = 4;
    let data = SyntheticClassification::generate(1024, 32, 10, 3.0, 5);
    let mut model = HloModel::new(rt.load_model("mlp").unwrap());
    let step_only = {
        let batch = data.batch(&(0..model.batch_size()).collect::<Vec<_>>());
        let mut params = model.init_params(0).unwrap();
        bench(2, iters, || {
            model.local_step(0, &mut params, &batch, 0.01).unwrap();
        })
        .median
    };
    let mut cfg = TrainConfig::quick(n, 1);
    cfg.max_iters_per_epoch = Some(8);
    cfg.eval_every_epochs = 0;
    let mut run_model = HloModel::new(rt.load_model("mlp").unwrap());
    let whole = bench(1, 5, || {
        let mut trainer = Trainer::new(&mut run_model, cfg.clone());
        std::hint::black_box(trainer.run(&data, &SgdFlavor::DecentralizedRing).unwrap());
    });
    // The run performs 8 iterations plus one final full-test-set eval.
    let per_iter = whole.median / 8;
    let overhead = per_iter
        .checked_sub(step_only * n as u32)
        .unwrap_or_default();
    println!(
        "n={n} workers: full iteration {} ({} per worker slot);\n\
         pure PJRT step {}; coordinator overhead (mixing + metrics + data + final\n\
         eval amortized) ≈ {} per iteration",
        fmt_duration(per_iter),
        fmt_duration(per_iter / n as u32),
        fmt_duration(step_only),
        fmt_duration(overhead),
    );
}
