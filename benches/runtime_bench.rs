//! PJRT runtime benchmarks — the per-iteration budget of the production
//! (HLO) path: executable load+compile time, `init`/`step`/`eval`
//! latency per model, and coordinator overhead (everything around the
//! PJRT call in a training iteration).
//!
//! Run: `cargo bench --bench runtime_bench` (needs `make artifacts`).

use ada_dist::coordinator::{HloModel, LocalModel};
use ada_dist::data::{Dataset, SyntheticClassification, SyntheticLm};
use ada_dist::runtime::PjRtRuntime;
use ada_dist::util::bench::{bench, env_usize, fmt_duration, Table};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("mlp/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return;
    }
    let iters = env_usize("ADA_BENCH_ITERS", 20);
    let rt = PjRtRuntime::cpu(&dir).expect("pjrt client");
    println!("platform: {}\n", rt.platform());

    println!("== artifact load + XLA compile ==");
    let mut t = Table::new(&["model", "load+compile (median)"]);
    for name in ["mlp", "cnn", "lstm", "transformer"] {
        let tm = bench(1, 3, || {
            std::hint::black_box(rt.load_model(name).unwrap());
        });
        t.row(vec![name.into(), fmt_duration(tm.median)]);
    }
    println!("{}", t.render());

    println!("== per-call latency (one worker-iteration = one `step`) ==");
    let mut t = Table::new(&["model", "P", "init", "step", "eval", "steps/s"]);
    for name in ["mlp", "cnn", "lstm", "transformer"] {
        let mut model = HloModel::new(rt.load_model(name).unwrap());
        let m = model.bundle().manifest.clone();
        let (bx, ex): (Box<dyn Dataset>, Box<dyn Dataset>) = match m.kind {
            ada_dist::runtime::ModelKind::Classification => (
                Box::new(SyntheticClassification::generate(
                    512, m.x_dim, m.num_outputs, 3.0, 1,
                )),
                Box::new(SyntheticClassification::generate(
                    512, m.x_dim, m.num_outputs, 3.0, 2,
                )),
            ),
            ada_dist::runtime::ModelKind::Lm => (
                Box::new(SyntheticLm::generate(512, m.x_dim, m.num_outputs, 2, 1)),
                Box::new(SyntheticLm::generate(512, m.x_dim, m.num_outputs, 2, 2)),
            ),
        };
        let train_batch = bx.batch(&(0..m.batch_size).collect::<Vec<_>>());
        let eval_batch = ex.batch(&(0..m.eval_batch_size).collect::<Vec<_>>());
        let mut params = model.init_params(0).unwrap();

        let t_init = bench(1, iters.min(10), || {
            std::hint::black_box(model.init_params(1).unwrap());
        });
        let t_step = bench(2, iters, || {
            model.local_step(0, &mut params, &train_batch, 0.01).unwrap();
        });
        let t_eval = bench(1, iters.min(10), || {
            std::hint::black_box(model.eval_sums(&params, &eval_batch).unwrap());
        });
        t.row(vec![
            name.into(),
            m.param_count.to_string(),
            fmt_duration(t_init.median),
            fmt_duration(t_step.median),
            fmt_duration(t_eval.median),
            format!("{:.0}", 1.0 / t_step.median.as_secs_f64()),
        ]);
    }
    println!("{}", t.render());

    println!("== coordinator overhead around the PJRT call ==");
    // Measure a full n-worker iteration and subtract n × step latency.
    use ada_dist::coordinator::{SgdFlavor, TrainConfig, Trainer};
    let n = 4;
    let data = SyntheticClassification::generate(1024, 32, 10, 3.0, 5);
    let mut model = HloModel::new(rt.load_model("mlp").unwrap());
    let step_only = {
        let batch = data.batch(&(0..model.batch_size()).collect::<Vec<_>>());
        let mut params = model.init_params(0).unwrap();
        bench(2, iters, || {
            model.local_step(0, &mut params, &batch, 0.01).unwrap();
        })
        .median
    };
    let mut cfg = TrainConfig::quick(n, 1);
    cfg.max_iters_per_epoch = Some(8);
    cfg.eval_every_epochs = 0;
    let mut run_model = HloModel::new(rt.load_model("mlp").unwrap());
    let whole = bench(1, 5, || {
        let mut trainer = Trainer::new(&mut run_model, cfg.clone());
        std::hint::black_box(trainer.run(&data, &SgdFlavor::DecentralizedRing).unwrap());
    });
    // The run performs 8 iterations plus one final full-test-set eval.
    let per_iter = whole.median / 8;
    let overhead = per_iter
        .checked_sub(step_only * n as u32)
        .unwrap_or_default();
    println!(
        "n={n} workers: full iteration {} ({} per worker slot);\n\
         pure PJRT step {}; coordinator overhead (mixing + metrics + data + final\n\
         eval amortized) ≈ {} per iteration",
        fmt_duration(per_iter),
        fmt_duration(per_iter / n as u32),
        fmt_duration(step_only),
        fmt_duration(overhead),
    );
}
