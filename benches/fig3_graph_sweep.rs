//! Figure 3 — final model accuracy of all five SGD implementations
//! across the four applications and the training scales, plus the
//! §3.2 "tuned" square-root-scaling runs (Observation 3).
//!
//! Paper shape to reproduce (81.25% of cells): `C_complete` best or
//! tied-best; among decentralized runs, more connections ⇒ better final
//! accuracy (`D_complete ≥ D_exponential ≥ D_torus ≥ D_ring`), with the
//! ordering sharpening as the scale grows; at the largest scales the
//! linear-scaled LR can destabilize the dense graphs, which sqrt
//! scaling (the `tuned_` series) repairs.
//!
//! Run: `cargo bench --bench fig3_graph_sweep`
//! (quick preset: 2 apps × scales {8,16}; ADA_BENCH_FULL=1: 4 apps ×
//! {8,…,512,1024}, with the synthetic datasets grown so shards stay
//! non-degenerate at the large scales and `ADA_BENCH_MAX_ITERS`
//! (default 25 full, 0 = uncapped) bounding iterations per epoch).
//! Runs on the parallel execution path by default —
//! `ADA_BENCH_THREADS` (0 = all cores) and `ADA_BENCH_FUSED=1`
//! control the engine; results are bit-identical for every thread count
//! (see `crate::exec`).

use ada_dist::dbench::{format_table, run_experiment, ExperimentSpec};
use ada_dist::optim::ScalingRule;
use ada_dist::util::bench::{env_flag, env_usize};

fn main() {
    let full = env_flag("ADA_BENCH_FULL");
    let scales: Vec<usize> = if full {
        vec![8, 16, 32, 64, 128, 256, 512, 1024]
    } else {
        vec![8, 16]
    };
    let epochs = env_usize("ADA_BENCH_EPOCHS", if full { 10 } else { 5 });
    let threads = env_usize("ADA_BENCH_THREADS", 0); // 0 = all cores
    let fused = env_flag("ADA_BENCH_FUSED");
    let max_iters = env_usize("ADA_BENCH_MAX_ITERS", if full { 25 } else { 0 });

    let mut apps = ExperimentSpec::four_applications();
    if !full {
        apps.truncate(2); // resnet20 + resnet50 analogs in the quick preset
    }
    for mut spec in apps {
        spec.scales = scales.clone();
        spec.epochs = epochs;
        spec.metrics_every = 2;
        spec.threads = threads;
        spec.fused = fused;
        // Scale-sweep support (ROADMAP: n=512–1024): grow each app's
        // dataset for ~16 batches per shard at the largest scale and
        // cap iterations so small scales keep bounded epochs.
        if full {
            let max_scale = *scales.iter().max().expect("scales");
            spec.workload
                .ensure_examples(max_scale * spec.workload.batch_size() * 16 * 20 / 17);
        }
        if max_iters > 0 {
            spec.max_iters_per_epoch = Some(max_iters);
        }
        let t0 = std::time::Instant::now();
        let cells = run_experiment(&spec).expect("sweep");
        println!(
            "{}",
            format_table(
                &format!("Fig 3: {} ({:.1?})", spec.name, t0.elapsed()),
                &cells
            )
        );

        // Tuned series: sqrt LR scaling at the largest scale (§3.2's fix
        // for the unconverged large-scale cells).
        let mut tuned = spec.clone();
        tuned.scaling = ScalingRule::Sqrt;
        tuned.scales = vec![*scales.last().unwrap()];
        let cells = run_experiment(&tuned).expect("tuned");
        println!(
            "{}",
            format_table(
                &format!("Fig 3 (tuned, sqrt scaling): {}", tuned.name),
                &cells
            )
        );
    }
    println!(
        "expected shape per app table: C_complete/D_complete on top, D_ring at\n\
         the bottom, gaps widening with scale; `tuned` rows recover accuracy\n\
         wherever the linear-scaled LR diverged or stalled."
    );
}
