//! Quickstart: train a small model with Ada's adaptive decentralized
//! SGD on 8 simulated workers and print the result.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Uses the AOT-compiled HLO model (the production path) when
//! `artifacts/` exists, else falls back to the pure-Rust surrogate so
//! the example always runs.

use ada_dist::coordinator::{LocalModel, SgdFlavor, TrainConfig, Trainer};
use ada_dist::coordinator::surrogate::MlpClassifier;
use ada_dist::data::SyntheticClassification;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let workers = 8;
    let epochs = 6;

    // 1. A dataset: synthetic CIFAR-like class clusters, sharded
    //    non-iid across workers by the trainer.
    let data = SyntheticClassification::generate(4096, 32, 10, 2.5, 42);

    // 2. A model: the AOT JAX/Pallas `mlp` via PJRT (pjrt builds with
    //    artifacts present), or the pure-Rust surrogate.
    #[cfg(feature = "pjrt")]
    let mut model: Box<dyn LocalModel> = {
        let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if artifact_dir.join("mlp/manifest.json").exists() {
            let rt = ada_dist::runtime::PjRtRuntime::cpu(&artifact_dir)?;
            println!("using HLO artifacts via PJRT ({})", rt.platform());
            Box::new(ada_dist::coordinator::HloModel::new(rt.load_model("mlp")?))
        } else {
            println!("artifacts not built — using the pure-Rust surrogate");
            Box::new(MlpClassifier::new(32, 64, 10, 16, 64, workers, 0.9))
        }
    };
    #[cfg(not(feature = "pjrt"))]
    let mut model: Box<dyn LocalModel> = {
        println!("pure-std build — using the pure-Rust surrogate");
        Box::new(MlpClassifier::new(32, 64, 10, 16, 64, workers, 0.9))
    };

    // 3. Ada: start near-complete (k0 = 7) and decay one step per epoch.
    let flavor = SgdFlavor::Ada { k0: 7, gamma_k: 1.0 };

    let mut trainer = Trainer::new(model.as_mut(), TrainConfig::quick(workers, epochs));
    let t0 = std::time::Instant::now();
    let (recorder, summary) = trainer.run(&data, &flavor)?;

    println!(
        "\ntrained {} for {} iterations in {:.1?}",
        summary.flavor,
        recorder.records().len(),
        t0.elapsed()
    );
    println!("final test accuracy: {:.3}", summary.final_eval.metric);
    println!("communication: {:.2} MB sent per worker", summary.bytes_per_node as f64 / 1e6);
    println!("accuracy curve (iteration, accuracy):");
    for (it, acc) in recorder.metric_series() {
        println!("  {it:>5}  {acc:.3}");
    }
    Ok(())
}
