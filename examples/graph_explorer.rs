//! Graph explorer: the topology design space at a chosen scale —
//! degree, edge count, spectral gap (mixing speed), and Summit-model
//! communication cost per gossip round, including the full Ada lattice
//! k-sweep. The tool behind DESIGN.md's topology discussion.
//!
//!     cargo run --release --example graph_explorer -- 96
//!     cargo run --release --example graph_explorer -- 1008 25560000

use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::simnet::{ClusterSpec, SimNet};
use ada_dist::util::bench::Table;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(96);
    let params: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(1_000_000);
    let net = SimNet::new(ClusterSpec::summit());

    println!(
        "== topology design space @ n = {n}, {params} params ({} Summit nodes) ==",
        n.div_ceil(6)
    );
    let mut t = Table::new(&[
        "graph",
        "degree",
        "edges",
        "gap(1-σ2)",
        "round ms",
        "inter-node MB",
        "rounds→consensus*",
    ]);
    let mut kinds = vec![
        GraphKind::Ring,
        GraphKind::Torus,
        GraphKind::RingLattice { k: 3 },
        GraphKind::Exponential,
        GraphKind::Hypercube,
        GraphKind::RandomRegular { d: 4, seed: 7 },
        GraphKind::Complete,
    ];
    // Ada lattice k-sweep: powers of two up to n/2.
    let mut k = 2;
    while k < n / 2 {
        kinds.push(GraphKind::AdaLattice { k });
        k *= 2;
    }
    for kind in kinds {
        let Ok(g) = CommGraph::build(kind, n) else { continue };
        let gap = g.spectral_gap();
        let cost = net.gossip_round(&g, params);
        // Rounds for the disagreement to contract by 1e3: σ2^r = 1e-3.
        let rounds = if gap >= 1.0 - 1e-9 {
            "1".to_string()
        } else {
            format!("{:.0}", (1e-3f64).ln() / (1.0 - gap).ln())
        };
        t.row(vec![
            kind.to_string(),
            g.degree().to_string(),
            g.edge_count().to_string(),
            format!("{gap:.6}"),
            format!("{:.3}", cost.time_s * 1e3),
            format!("{:.1}", cost.inter_node_bytes as f64 / 1e6),
            rounds,
        ]);
    }
    println!("{}", t.render());
    println!("* rounds for cross-replica disagreement to shrink 1000× (σ2^r = 1e-3)");
    println!(
        "\nreading: Ada exploits the left-to-right sweep of this table — start where\n\
         the gap is large (fast consensus, expensive rounds), finish where rounds\n\
         are cheap (small k) once replicas agree."
    );
    Ok(())
}
