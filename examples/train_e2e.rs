//! End-to-end driver: decentralized training of a transformer LM on
//! synthetic corpus data through the full three-layer stack — JAX/Pallas
//! AOT artifacts executed via PJRT from the Rust coordinator, gossip
//! averaging over Ada's adaptive lattice — logging the loss curve.
//!
//!     make artifacts
//!     cargo run --release --example train_e2e
//!
//! Environment knobs:
//!   ADA_E2E_MODEL    transformer (default) | transformer_e2e (~14M) |
//!                    transformer_100m (lower the artifact first with
//!                    `python -m compile.aot --models transformer_100m`)
//!   ADA_E2E_WORKERS  simulated GPUs (default 4)
//!   ADA_E2E_EPOCHS   epochs (default 8; each epoch = shard/batch iters)
//!   ADA_E2E_SEQS     dataset size in sequences (default 2048)
//!
//! The run is recorded to out/train_e2e.jsonl and summarized in
//! EXPERIMENTS.md §E2E.

use ada_dist::coordinator::{HloModel, SgdFlavor, TrainConfig, Trainer};
use ada_dist::coordinator::trainer::LrPolicy;
use ada_dist::data::{ShardStrategy, SyntheticLm};
use ada_dist::optim::LrSchedule;
use ada_dist::runtime::PjRtRuntime;
use ada_dist::util::bench::env_usize;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let model_name =
        std::env::var("ADA_E2E_MODEL").unwrap_or_else(|_| "transformer".to_string());
    let workers = env_usize("ADA_E2E_WORKERS", 4);
    let epochs = env_usize("ADA_E2E_EPOCHS", 8);
    let n_seqs = env_usize("ADA_E2E_SEQS", 2048);

    let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = PjRtRuntime::cpu(&artifact_dir)?;
    let t_load = std::time::Instant::now();
    let bundle = rt.load_model(&model_name)?;
    let manifest = bundle.manifest.clone();
    println!(
        "loaded {model_name}: {} params, seq {}, vocab {} (compile {:.1?})",
        manifest.param_count,
        manifest.x_dim,
        manifest.num_outputs,
        t_load.elapsed()
    );
    let mut model = HloModel::new(bundle);

    let data = SyntheticLm::generate(n_seqs, manifest.x_dim, manifest.num_outputs, 3, 7);

    let k0 = (workers - 1).max(2);
    let flavor = SgdFlavor::Ada {
        k0,
        gamma_k: k0 as f64 / (epochs as f64 * 0.75),
    };
    let config = TrainConfig {
        n_workers: workers,
        epochs,
        seed: 7,
        lr: LrPolicy::Fixed {
            schedule: LrSchedule::bench_default(0.3, 1.0, 1.0, epochs as f64),
        },
        shard: ShardStrategy::Contiguous,
        test_frac: 0.1,
        eval_every_epochs: 1,
        metrics_every: 4,
        max_iters_per_epoch: None,
        track_layers: vec![0, 2],
        central_momentum: 0.0,
        drop_prob: 0.0,
        threads: 0,
        fused: false,
        fused_momentum: 0.0,
        pipeline: false,
        bucket_kb: 0,
        record_path: Some("out/train_e2e.jsonl".into()),
        faults: None,
        staleness_bound: 0,
    };

    println!(
        "training: {workers} workers × {epochs} epochs, Ada(k0={k0}), \
         {} sequences, batch {}/worker",
        n_seqs, manifest.batch_size
    );
    let mut trainer = Trainer::new(&mut model, config);
    let t0 = std::time::Instant::now();
    let (recorder, summary) = trainer.run(&data, &flavor)?;
    let elapsed = t0.elapsed();

    // Loss curve: print every ~20th iteration.
    println!("\nloss curve (iteration, epoch, train_loss, k-degree):");
    let records = recorder.records();
    let stride = (records.len() / 25).max(1);
    for r in records.iter().step_by(stride) {
        println!(
            "  {:>6}  {:>3}  {:>8.4}  deg={}",
            r.iteration, r.epoch, r.train_loss, r.graph_degree
        );
    }
    if let Some(last) = records.last() {
        println!(
            "  {:>6}  {:>3}  {:>8.4}  deg={}",
            last.iteration, last.epoch, last.train_loss, last.graph_degree
        );
    }

    println!("\nperplexity curve (iteration, test ppl):");
    for (it, ppl) in recorder.metric_series() {
        println!("  {it:>6}  {ppl:.2}");
    }

    let first_loss = records.first().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let last_loss = records.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
    println!(
        "\n=== E2E summary ===\n\
         model {model_name} ({} params) × {workers} workers, {} iterations in {elapsed:.1?}\n\
         train loss {first_loss:.4} → {last_loss:.4}; final test ppl {:.2} \
         (uniform baseline {})\n\
         comm sent per worker: {:.2} MB; diverged: {}",
        manifest.param_count,
        records.len(),
        summary.final_eval.metric,
        manifest.num_outputs,
        summary.bytes_per_node as f64 / 1e6,
        summary.diverged,
    );
    println!("records written to out/train_e2e.jsonl");
    if summary.diverged {
        return Err("training diverged".into());
    }
    if !(last_loss < first_loss) {
        return Err("loss must decrease over the run".into());
    }
    Ok(())
}
