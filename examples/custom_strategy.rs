//! Custom SGD scenarios registered from *outside* `coordinator/` and
//! `topology/`, trained end-to-end through the DBench pipeline — the
//! open strategy **and** topology layers in ~100 lines.
//!
//!     cargo run --release --example custom_strategy
//!
//! Two extensions, neither touching `ada_dist` source:
//!
//! 1. A combine strategy: **local SGD with periodic averaging**
//!    (Stich 2018) — workers run momentum-SGD locally and only gossip
//!    every `PERIOD` iterations, cutting communication by ~PERIOD×
//!    against the same graph ([`CombineStrategy`] + strategy registry).
//! 2. A topology policy: **loss-plateau decay** — keep the lattice
//!    dense while the training loss is still falling fast, decay its
//!    coordination number once progress plateaus. It reads
//!    [`TrainSignals::train_loss`], one of the structured feedback
//!    signals every policy receives per epoch ([`TopologyPolicy`] +
//!    topology registry, referenced from a plan cell by name).

use ada_dist::coordinator::strategy::{CombineStrategy, StepCtx, StrategyInstance};
use ada_dist::coordinator::SgdFlavor;
use ada_dist::dbench::{format_table, ExperimentSpec, SessionPlan, StrategyRef, TopologyRef};
use ada_dist::error::Result;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::topology::{FnSchedule, TopologyPolicy, TrainSignals};
use ada_dist::ReplicaMatrix;
use std::sync::Mutex;

/// How many local steps between averaging rounds.
const PERIOD: usize = 4;

/// Local SGD: every iteration runs the fused local step on each worker;
/// only every `period`-th round gossips (here over the complete graph,
/// i.e. classic periodic full averaging).
struct LocalSgd {
    period: usize,
    rounds: usize,
}

impl CombineStrategy for LocalSgd {
    fn name(&self) -> &str {
        "local_sgd"
    }

    fn local_phase(&mut self, ctx: &mut StepCtx<'_>, replicas: &mut ReplicaMatrix) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            loss_sum += ctx.model.local_step(w, replicas.row_mut(w), &batch, ctx.lr)? as f64;
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        self.rounds += 1;
        if self.rounds % self.period != 0 {
            return Ok((0, 0)); // local round: no exchange, no bytes
        }
        let g = ctx.graph.expect("schedule provides a graph");
        match ctx.active {
            Some(active) => ctx.engine.mix_active(g, replicas, active),
            None => ctx.engine.mix(g, replicas),
        }
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}

/// A custom topology policy: hold a dense `k`-lattice while the mean
/// training loss still improves by at least `min_drop` per epoch, halve
/// `k` (floor 2) once it plateaus. Entirely out-of-crate: it only
/// implements [`TopologyPolicy`] and reads the [`TrainSignals`] the
/// session feeds every policy.
struct LossPlateauDecay {
    n: usize,
    min_drop: f64,
    state: Mutex<PlateauState>,
}

struct PlateauState {
    k: usize,
    last_loss: Option<f64>,
}

impl LossPlateauDecay {
    fn new(n: usize, k0: usize, min_drop: f64) -> Self {
        LossPlateauDecay {
            n,
            min_drop,
            state: Mutex::new(PlateauState { k: k0.max(2), last_loss: None }),
        }
    }
}

impl TopologyPolicy for LossPlateauDecay {
    fn graph_for(&self, _epoch: usize, _iter: usize) -> Result<CommGraph> {
        let k = self.state.lock().expect("state").k;
        CommGraph::build(GraphKind::AdaLattice { k }, self.n)
    }

    fn observe(&mut self, signals: &TrainSignals) {
        let mut st = self.state.lock().expect("state");
        if let Some(prev) = st.last_loss {
            if prev - signals.train_loss < self.min_drop {
                st.k = (st.k / 2).max(2); // plateau: halve the density
            }
        }
        st.last_loss = Some(signals.train_loss);
    }

    fn name(&self) -> String {
        format!("loss_plateau(min_drop={})", self.min_drop)
    }
}

fn main() -> std::result::Result<(), Box<dyn std::error::Error>> {
    let workers = 8;
    let mut spec = ExperimentSpec::resnet20_analog();
    spec.scales = vec![workers];
    spec.epochs = 4;
    spec.flavors = vec![
        SgdFlavor::DecentralizedRing,
        SgdFlavor::DecentralizedComplete,
    ];

    // The pipeline: baseline flavors from the spec, plus one cell for
    // the custom strategy, resolved by name against the extended
    // registry.
    let mut plan = SessionPlan::from_spec(&spec);
    plan.registry.register("D_local_sgd", |p| {
        let n = p.n_workers;
        Ok(StrategyInstance {
            label: "D_local_sgd".into(),
            schedule: Some(Box::new(FnSchedule::new("complete", move |_| {
                CommGraph::build(GraphKind::Complete, n)
            }))),
            k_neighbors: n.saturating_sub(1),
            combine: Some(Box::new(LocalSgd { period: PERIOD, rounds: 0 })),
        })
    });
    plan.push_cell(
        workers,
        spec.seed,
        StrategyRef::named("D_local_sgd"),
        spec.train_config(workers),
    );

    // The custom topology policy: registered by name in the plan's
    // topology registry, then referenced from a cell that keeps the
    // stock gossip combine but swaps the graph policy.
    plan.topologies.register("loss_plateau", |n, params| {
        Ok(Box::new(LossPlateauDecay::new(
            n,
            params.usize_or("k0", n.saturating_sub(1).max(2))?,
            params.f64_or("min_drop", 0.02)?,
        )))
    });
    plan.push_cell_with_topology(
        workers,
        spec.seed,
        StrategyRef::Flavor(SgdFlavor::DecentralizedComplete),
        TopologyRef::parse("loss_plateau:min_drop=0.02")?,
        spec.train_config(workers),
    );

    let t0 = std::time::Instant::now();
    let cells = plan.run()?;
    println!(
        "{}",
        format_table(
            &format!(
                "custom strategy + custom topology policy vs gossip baselines \
                 @ {workers} workers ({:.1?})",
                t0.elapsed()
            ),
            &cells
        )
    );
    println!(
        "expected shape: D_local_sgd sends ~1/{PERIOD} of D_complete's bytes while\n\
         staying close in accuracy (periodic averaging trades freshness for cost);\n\
         D_complete+loss_plateau starts dense and sheds neighbors once the loss\n\
         plateaus, landing between D_complete and D_ring in bytes/node."
    );
    Ok(())
}
