//! A custom SGD scenario registered from *outside* `coordinator/` and
//! trained end-to-end through the DBench pipeline — the open strategy
//! layer in ~60 lines.
//!
//!     cargo run --release --example custom_strategy
//!
//! The scenario is **local SGD with periodic averaging** (Stich 2018):
//! workers run momentum-SGD locally and only gossip every `PERIOD`
//! iterations, cutting communication by ~PERIOD× against the same
//! graph. It needs a new per-iteration combine rule — exactly what
//! [`CombineStrategy`] opens up: implement the trait, register a
//! constructor under a name, add a plan cell referencing that name.
//! No `ada_dist` source is touched.

use ada_dist::coordinator::strategy::{CombineStrategy, StepCtx, StrategyInstance};
use ada_dist::coordinator::SgdFlavor;
use ada_dist::dbench::{format_table, ExperimentSpec, SessionPlan, StrategyRef};
use ada_dist::error::Result;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::topology::FnSchedule;
use ada_dist::ReplicaMatrix;

/// How many local steps between averaging rounds.
const PERIOD: usize = 4;

/// Local SGD: every iteration runs the fused local step on each worker;
/// only every `period`-th round gossips (here over the complete graph,
/// i.e. classic periodic full averaging).
struct LocalSgd {
    period: usize,
    rounds: usize,
}

impl CombineStrategy for LocalSgd {
    fn name(&self) -> &str {
        "local_sgd"
    }

    fn local_phase(&mut self, ctx: &mut StepCtx<'_>, replicas: &mut ReplicaMatrix) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            loss_sum += ctx.model.local_step(w, replicas.row_mut(w), &batch, ctx.lr)? as f64;
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        self.rounds += 1;
        if self.rounds % self.period != 0 {
            return Ok((0, 0)); // local round: no exchange, no bytes
        }
        let g = ctx.graph.expect("schedule provides a graph");
        match ctx.active {
            Some(active) => ctx.engine.mix_active(g, replicas, active),
            None => ctx.engine.mix(g, replicas),
        }
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}

fn main() -> std::result::Result<(), Box<dyn std::error::Error>> {
    let workers = 8;
    let mut spec = ExperimentSpec::resnet20_analog();
    spec.scales = vec![workers];
    spec.epochs = 4;
    spec.flavors = vec![
        SgdFlavor::DecentralizedRing,
        SgdFlavor::DecentralizedComplete,
    ];

    // The pipeline: baseline flavors from the spec, plus one cell for
    // the custom strategy, resolved by name against the extended
    // registry.
    let mut plan = SessionPlan::from_spec(&spec);
    plan.registry.register("D_local_sgd", |p| {
        let n = p.n_workers;
        Ok(StrategyInstance {
            label: "D_local_sgd".into(),
            schedule: Some(Box::new(FnSchedule::new("complete", move |_| {
                CommGraph::build(GraphKind::Complete, n)
            }))),
            k_neighbors: n.saturating_sub(1),
            combine: Some(Box::new(LocalSgd { period: PERIOD, rounds: 0 })),
        })
    });
    plan.push_cell(
        workers,
        spec.seed,
        StrategyRef::named("D_local_sgd"),
        spec.train_config(workers),
    );

    let t0 = std::time::Instant::now();
    let cells = plan.run()?;
    println!(
        "{}",
        format_table(
            &format!(
                "custom strategy: local SGD (sync every {PERIOD}) vs gossip baselines \
                 @ {workers} workers ({:.1?})",
                t0.elapsed()
            ),
            &cells
        )
    );
    println!(
        "expected shape: D_local_sgd sends ~1/{PERIOD} of D_complete's bytes while\n\
         staying close in accuracy (periodic averaging trades freshness for cost)."
    );
    Ok(())
}
