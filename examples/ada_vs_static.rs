//! Ada vs static topologies on one workload: the accuracy /
//! communication trade-off of Fig 7 in one table, plus the per-epoch
//! variance trace that motivates the adaptive schedule (Observation 4).
//!
//!     cargo run --release --example ada_vs_static -- [workers] [epochs]

use ada_dist::coordinator::SgdFlavor;
use ada_dist::dbench::{run_cell, ExperimentSpec};
use ada_dist::util::bench::Table;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let workers: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(16);
    let epochs: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(8);

    let mut spec = ExperimentSpec::densenet_analog();
    spec.epochs = epochs;
    spec.metrics_every = 1;

    let k0 = (workers - 1).max(4);
    let flavors = vec![
        SgdFlavor::CentralizedComplete,
        SgdFlavor::DecentralizedComplete,
        SgdFlavor::DecentralizedRing,
        SgdFlavor::DecentralizedTorus,
        SgdFlavor::Ada { k0, gamma_k: k0 as f64 / (epochs as f64 * 0.75) },
        SgdFlavor::VarianceAdaptive { k0, step: 2, threshold: 0.002, patience: 1 },
    ];

    println!(
        "== {} @ {workers} workers, {epochs} epochs ==",
        spec.workload.name()
    );
    let mut t = Table::new(&[
        "flavor",
        "final acc",
        "MB/node",
        "acc per GB",
        "gini e1",
        "gini mid",
        "gini end",
    ]);
    let mut curves: Vec<(String, Vec<(usize, f64)>)> = Vec::new();
    for flavor in flavors {
        let cell = run_cell(&spec, workers, &flavor)?;
        let rec = &cell.recorder;
        let total = rec.records().len();
        let w = (total / 6).max(1);
        let gini = |r: std::ops::Range<usize>| rec.mean_gini(r);
        let mb = cell.summary.bytes_per_node as f64 / 1e6;
        t.row(vec![
            cell.flavor.clone(),
            format!("{:.4}", cell.summary.final_eval.metric),
            format!("{mb:.1}"),
            format!("{:.3}", cell.summary.final_eval.metric / (mb / 1e3).max(1e-9)),
            format!("{:.6}", gini(1..w + 1)),
            format!("{:.6}", gini(total / 2..total / 2 + w)),
            format!("{:.6}", gini(total - w..total)),
        ]);
        curves.push((cell.flavor.clone(), rec.metric_series()));
    }
    println!("{}", t.render());

    println!("accuracy curves (iteration: flavor=acc):");
    let max_pts = curves.iter().map(|(_, c)| c.len()).max().unwrap_or(0);
    for i in 0..max_pts {
        let mut line = String::new();
        for (name, c) in &curves {
            if let Some((it, acc)) = c.get(i) {
                line.push_str(&format!("{name}@{it}={acc:.3}  "));
            }
        }
        println!("  {line}");
    }
    println!(
        "\nreading: Ada should match the complete graphs' accuracy at a fraction\n\
         of the MB/node; the static ring is cheapest but trails in accuracy;\n\
         the variance-triggered variant adapts on the measured gini instead of\n\
         an epoch clock."
    );
    Ok(())
}
