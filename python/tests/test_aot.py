"""AOT pipeline: lowering produces loadable HLO text + sane manifests."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as model_lib
from compile.models import get_spec


class TestHloText:
    def test_lowering_emits_hlo_module(self):
        spec = get_spec("mlp")
        init_fn, step_fn, _, manifest = model_lib.build_functions(spec)
        args = model_lib.example_args(spec, manifest["param_count"])
        text = aot.to_hlo_text(init_fn, args["init"])
        assert text.startswith("HloModule")
        assert "f32[2762]" in text, "flat param type must appear"

    def test_step_hlo_contains_fused_update_loop(self):
        # The pallas fused_sgd lowers (interpret mode) to a while loop
        # over grid tiles inside the same step module.
        spec = get_spec("mlp")
        _, step_fn, _, manifest = model_lib.build_functions(spec)
        args = model_lib.example_args(spec, manifest["param_count"])
        text = aot.to_hlo_text(step_fn, args["step"])
        assert text.startswith("HloModule")
        assert "while" in text, "interpret-mode pallas grid loop expected"

    def test_return_tuple_convention(self):
        # Rust unwraps a single tuple output — lowering must return one.
        spec = get_spec("mlp")
        init_fn, _, _, manifest = model_lib.build_functions(spec)
        args = model_lib.example_args(spec, manifest["param_count"])
        text = aot.to_hlo_text(init_fn, args["init"])
        assert "ROOT" in text and "tuple" in text


class TestArtifactTree:
    @pytest.fixture(scope="class")
    def artifact_dir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        aot.lower_model("mlp", str(out))
        aot.lower_gossip([4], [2762], str(out))
        return out

    def test_model_files_exist(self, artifact_dir):
        for f in ["init.hlo.txt", "step.hlo.txt", "eval.hlo.txt", "manifest.json"]:
            assert (artifact_dir / "mlp" / f).exists()

    def test_manifest_schema(self, artifact_dir):
        m = json.loads((artifact_dir / "mlp" / "manifest.json").read_text())
        for key in [
            "name",
            "kind",
            "param_count",
            "x_dim",
            "y_dim",
            "batch_size",
            "eval_batch_size",
            "num_outputs",
            "layer_ranges",
            "files",
        ]:
            assert key in m, f"manifest missing {key}"
        assert m["kind"] in ("classification", "lm")
        assert m["files"]["step"] == "step.hlo.txt"

    def test_gossip_manifest_lists_variants(self, artifact_dir):
        g = json.loads((artifact_dir / "gossip" / "manifest.json").read_text())
        assert [4, 2762] in g["variants"]
        assert (artifact_dir / "gossip" / "mix_n4_p2762.hlo.txt").exists()

    def test_roundtrip_through_xla_client(self, artifact_dir):
        # Compile + execute the lowered init through the same CPU PJRT
        # python client jax uses — a proxy for the Rust loader path.
        from jax._src.lib import xla_client as xc

        text = (artifact_dir / "mlp" / "init.hlo.txt").read_text()
        # The HLO text parses back into a computation.
        assert text.startswith("HloModule")
        spec = get_spec("mlp")
        init_fn, _, _, _ = model_lib.build_functions(spec)
        (flat,) = init_fn(jnp.int32(0))
        assert flat.shape[0] == 2762
