"""L1 kernel correctness: Pallas vs pure-jnp oracles, with hypothesis
sweeping shapes and dtypes-adjacent parameters."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_sgd, gossip_mix, vmem_report
from compile.kernels.ref import fused_sgd_ref, gossip_mix_ref


def mixing_matrix(n: int, seed: int) -> np.ndarray:
    """A random row-stochastic mixing matrix."""
    rng = np.random.RandomState(seed)
    w = rng.rand(n, n).astype(np.float32) + 0.1
    return w / w.sum(axis=1, keepdims=True)


class TestGossipMix:
    @pytest.mark.parametrize("n", [2, 4, 8, 32])
    @pytest.mark.parametrize("p", [1, 7, 2048, 5000])
    def test_matches_reference(self, n, p):
        w = mixing_matrix(n, seed=n)
        theta = np.random.RandomState(p).randn(n, p).astype(np.float32)
        got = gossip_mix(w, theta)
        want = gossip_mix_ref(w, theta)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_non_divisible_padding(self):
        # p deliberately not a multiple of the tile width.
        n, p = 4, 2048 + 129
        w = mixing_matrix(n, 0)
        theta = np.random.RandomState(0).randn(n, p).astype(np.float32)
        np.testing.assert_allclose(
            gossip_mix(w, theta), gossip_mix_ref(w, theta), rtol=1e-5, atol=1e-6
        )

    def test_identity_matrix_is_noop(self):
        n, p = 8, 100
        theta = np.random.RandomState(1).randn(n, p).astype(np.float32)
        got = gossip_mix(np.eye(n, dtype=np.float32), theta)
        np.testing.assert_allclose(got, theta, rtol=1e-6)

    def test_uniform_matrix_reaches_consensus(self):
        n, p = 8, 50
        theta = np.random.RandomState(2).randn(n, p).astype(np.float32)
        w = np.full((n, n), 1.0 / n, np.float32)
        got = np.asarray(gossip_mix(w, theta))
        mean = theta.mean(axis=0)
        for i in range(n):
            np.testing.assert_allclose(got[i], mean, rtol=1e-4, atol=1e-5)

    def test_preserves_global_mean(self):
        # Doubly stochastic W => column means invariant.
        n, p = 6, 333
        w = mixing_matrix(n, 3)
        w = (w + w.T) / 2.0
        w = w / w.sum(axis=1, keepdims=True)  # approx doubly stochastic
        # Sinkhorn a few rounds to make it properly doubly stochastic.
        for _ in range(50):
            w = w / w.sum(axis=0, keepdims=True)
            w = w / w.sum(axis=1, keepdims=True)
        theta = np.random.RandomState(4).randn(n, p).astype(np.float32)
        got = np.asarray(gossip_mix(w.astype(np.float32), theta))
        np.testing.assert_allclose(
            got.mean(axis=0), theta.mean(axis=0), rtol=1e-3, atol=1e-5
        )

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            gossip_mix(np.eye(3, dtype=np.float32), np.zeros((4, 10), np.float32))

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=16),
        p=st.integers(min_value=1, max_value=600),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        tile=st.sampled_from([64, 128, 2048]),
    )
    def test_hypothesis_shape_sweep(self, n, p, seed, tile):
        w = mixing_matrix(n, seed % 1000)
        theta = np.random.RandomState(seed % 1000 + 1).randn(n, p).astype(np.float32)
        got = gossip_mix(w, theta, tile_p=tile)
        np.testing.assert_allclose(got, gossip_mix_ref(w, theta), rtol=2e-5, atol=1e-5)

    def test_vmem_report_within_budget(self):
        # DESIGN.md §Hardware-Adaptation: the default tiling must fit a
        # 16 MiB VMEM with room for double-buffering at n = 64.
        rep = vmem_report(64, 25_560_000)
        assert rep["vmem_bytes"] * 2 < 16 * 2**20
        assert rep["mxu_row_fill"] == 0.5
        assert rep["grid_steps"] == -(-25_560_000 // rep["tile_p"])


class TestFusedSgd:
    @pytest.mark.parametrize("p", [1, 100, 8192, 8193, 50_000])
    def test_matches_reference(self, p):
        params = np.random.RandomState(p).randn(p).astype(np.float32)
        grads = np.random.RandomState(p + 1).randn(p).astype(np.float32)
        got = fused_sgd(params, grads, jnp.float32(0.05))
        np.testing.assert_allclose(
            got, fused_sgd_ref(params, grads, 0.05), rtol=1e-6, atol=1e-7
        )

    def test_weight_decay(self):
        p = 1000
        params = np.random.RandomState(0).randn(p).astype(np.float32)
        grads = np.zeros(p, np.float32)
        got = fused_sgd(params, grads, jnp.float32(1.0), weight_decay=0.1)
        np.testing.assert_allclose(got, params * 0.9, rtol=1e-6)

    def test_zero_lr_is_identity(self):
        p = 500
        params = np.random.RandomState(1).randn(p).astype(np.float32)
        grads = np.random.RandomState(2).randn(p).astype(np.float32)
        got = fused_sgd(params, grads, jnp.float32(0.0))
        np.testing.assert_allclose(got, params, rtol=0, atol=0)

    def test_lr_is_traced_not_baked(self):
        # One artifact must serve every LR schedule value.
        p = 64
        params = np.zeros(p, np.float32)
        grads = np.ones(p, np.float32)
        a = np.asarray(fused_sgd(params, grads, jnp.float32(0.1)))
        b = np.asarray(fused_sgd(params, grads, jnp.float32(0.2)))
        assert not np.allclose(a, b)
        np.testing.assert_allclose(b, 2 * a, rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        p=st.integers(min_value=1, max_value=20_000),
        lr=st.floats(min_value=0.0, max_value=10.0, width=32),
        wd=st.sampled_from([0.0, 1e-4, 0.1]),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    def test_hypothesis_sweep(self, p, lr, wd, seed):
        params = np.random.RandomState(seed).randn(p).astype(np.float32)
        grads = np.random.RandomState(seed + 1).randn(p).astype(np.float32)
        got = fused_sgd(params, grads, jnp.float32(lr), weight_decay=wd)
        want = fused_sgd_ref(params, grads, np.float32(lr), wd)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_mismatched_shapes(self):
        with pytest.raises(ValueError):
            fused_sgd(np.zeros(4, np.float32), np.zeros(5, np.float32), 0.1)
