"""L2 model correctness: shapes, determinism, learnability, gradient
validity, and the flat-layout contract with the Rust coordinator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.models import DEFAULT_MODELS, get_spec
from compile.models import transformer as transformer_mod


def make_batch(spec, batch_size, seed=0):
    rng = np.random.RandomState(seed)
    if spec.kind == "classification":
        x = rng.randn(batch_size, spec.x_dim).astype(np.float32)
        y = rng.randint(0, spec.num_outputs, (batch_size,)).astype(np.int32)
    else:
        x = rng.randint(0, spec.num_outputs, (batch_size, spec.x_dim)).astype(
            np.float32
        )
        y = rng.randint(0, spec.num_outputs, (batch_size, spec.y_dim)).astype(np.int32)
    return x, y


@pytest.fixture(scope="module", params=DEFAULT_MODELS)
def built(request):
    spec = get_spec(request.param)
    fns = model_lib.build_functions(spec)
    return request.param, spec, fns


class TestAllModels:
    def test_init_is_deterministic_in_seed(self, built):
        _, _, (init_fn, _, _, _) = built
        (a,) = init_fn(jnp.int32(7))
        (b,) = init_fn(jnp.int32(7))
        (c,) = init_fn(jnp.int32(8))
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_param_count_matches_manifest(self, built):
        _, _, (init_fn, _, _, manifest) = built
        (flat,) = init_fn(jnp.int32(0))
        assert flat.shape == (manifest["param_count"],)
        assert flat.dtype == jnp.float32

    def test_layer_ranges_tile_param_vector(self, built):
        _, _, (_, _, _, manifest) = built
        ranges = manifest["layer_ranges"]
        assert ranges[0][0] == 0
        assert ranges[-1][1] == manifest["param_count"]
        for (a0, a1), (b0, _) in zip(ranges, ranges[1:]):
            assert a1 == b0, "ranges must be contiguous"
            assert a0 < a1

    def test_step_reduces_loss_on_fixed_batch(self, built):
        _, spec, (init_fn, step_fn, _, _) = built
        (flat,) = init_fn(jnp.int32(0))
        x, y = make_batch(spec, spec.batch_size)
        lr = jnp.float32(0.1)
        _, loss0 = step_fn(flat, x, y, lr)
        for _ in range(5):
            flat, loss = step_fn(flat, x, y, lr)
        assert float(loss) < float(loss0), "5 steps on one batch must overfit"

    def test_step_loss_is_finite_and_positive(self, built):
        _, spec, (init_fn, step_fn, _, _) = built
        (flat,) = init_fn(jnp.int32(3))
        x, y = make_batch(spec, spec.batch_size, seed=3)
        new, loss = step_fn(flat, x, y, jnp.float32(0.05))
        assert np.isfinite(float(loss)) and float(loss) > 0
        assert np.all(np.isfinite(np.asarray(new)))

    def test_eval_sums_scale_with_batch(self, built):
        _, spec, (init_fn, _, eval_fn, _) = built
        (flat,) = init_fn(jnp.int32(1))
        x, y = make_batch(spec, spec.eval_batch_size, seed=5)
        loss_sum, metric_sum = eval_fn(flat, x, y)
        assert np.isfinite(float(loss_sum))
        if spec.kind == "classification":
            assert 0.0 <= float(metric_sum) <= spec.eval_batch_size
        else:
            assert float(metric_sum) == spec.eval_batch_size * spec.y_dim

    def test_zero_lr_step_keeps_params(self, built):
        _, spec, (init_fn, step_fn, _, _) = built
        (flat,) = init_fn(jnp.int32(2))
        x, y = make_batch(spec, spec.batch_size, seed=2)
        new, _ = step_fn(flat, x, y, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(new), np.asarray(flat))

    def test_step_matches_manual_grad_descent(self, built):
        # The fused pallas update inside step == params - lr * grad.
        _, spec, (init_fn, step_fn, _, manifest) = built
        (flat,) = init_fn(jnp.int32(4))
        x, y = make_batch(spec, spec.batch_size, seed=4)
        _, _, unravel = __import__(
            "compile.models.common", fromlist=["flatten_info"]
        ).flatten_info(spec)

        def loss_flat(f):
            return spec.loss_fn(unravel(f), x, y)

        grads = jax.grad(loss_flat)(flat)
        lr = jnp.float32(0.05)
        want = np.asarray(flat) - 0.05 * np.asarray(grads)
        got, _ = step_fn(flat, x, y, lr)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


class TestMlpMatchesRustSurrogate:
    """The `mlp` flat layout is a contract with the Rust MlpClassifier."""

    def test_flat_layout_is_w1_b1_w2_b2(self):
        spec = get_spec("mlp")
        _, _, _, manifest = model_lib.build_functions(spec)
        d, h, c = 32, 64, 10
        assert manifest["layer_ranges"] == [
            [0, h * d],
            [h * d, h * d + h],
            [h * d + h, h * d + h + c * h],
            [h * d + h + c * h, h * d + h + c * h + c],
        ]

    def test_forward_formula(self):
        # logits = W2 tanh(W1 x + b1) + b2 with row-major W blocks —
        # exactly the Rust surrogate's formula.
        spec = get_spec("mlp")
        init_fn, step_fn, _, manifest = model_lib.build_functions(spec)
        (flat,) = init_fn(jnp.int32(0))
        flat_np = np.asarray(flat)
        d, h, c = 32, 64, 10
        w1 = flat_np[: h * d].reshape(h, d)
        b1 = flat_np[h * d : h * d + h]
        w2 = flat_np[h * d + h : h * d + h + c * h].reshape(c, h)
        b2 = flat_np[h * d + h + c * h :]
        x, y = make_batch(spec, spec.batch_size, seed=9)
        logits = np.tanh(x @ w1.T + b1) @ w2.T + b2
        logp = logits - np.log(np.exp(logits - logits.max(1, keepdims=True)).sum(1, keepdims=True)) - logits.max(1, keepdims=True)
        want_loss = -logp[np.arange(len(y)), y].mean()
        _, got_loss = step_fn(flat, x, y, jnp.float32(0.0))
        np.testing.assert_allclose(float(got_loss), want_loss, rtol=1e-5)


class TestTransformerPresets:
    def test_param_count_formula_matches(self):
        for preset in ["transformer", "transformer_e2e"]:
            spec = transformer_mod.spec(preset)
            _, _, _, manifest = model_lib.build_functions(spec)
            assert manifest["param_count"] == transformer_mod.param_count(preset)

    def test_100m_preset_is_100m(self):
        # Executability-proof preset really is ~100M params.
        assert transformer_mod.param_count("transformer_100m") > 95_000_000

    def test_causality(self):
        # Changing a future token must not change past logits.
        spec = get_spec("transformer")
        cfg = transformer_mod.PRESETS["transformer"]
        params = spec.init_raw(jax.random.PRNGKey(0))
        x = np.zeros((1, cfg.seq), np.float32)
        base = transformer_mod._forward(params, jnp.asarray(x), cfg)
        x2 = x.copy()
        x2[0, -1] = 5.0
        pert = transformer_mod._forward(params, jnp.asarray(x2), cfg)
        np.testing.assert_allclose(
            np.asarray(base)[0, : cfg.seq - 1],
            np.asarray(pert)[0, : cfg.seq - 1],
            rtol=1e-5,
            atol=1e-6,
        )
        assert not np.allclose(np.asarray(base)[0, -1], np.asarray(pert)[0, -1])
