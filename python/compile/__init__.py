"""Build-time Python: JAX models (L2) + Pallas kernels (L1), AOT-lowered
to HLO text artifacts executed from the Rust coordinator via PJRT.
Never imported at runtime."""
