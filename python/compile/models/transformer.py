"""Decoder-only transformer LM — the scalable workload for the
end-to-end driver (ResNet50/ImageNet stands in at benchmark scale; this
is the model the e2e example trains for a few hundred steps).

Pre-norm blocks, causal attention, learned positional embeddings.
Presets:
  * ``transformer``       — tiny (tests/benches; ~0.2M params)
  * ``transformer_e2e``   — ~14M params, the loss-curve driver
  * ``transformer_100m``  — ~101M params, executability proof
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.models.common import (
    ModelSpec,
    cross_entropy_mean,
    token_nll_sum,
    uniform_init,
)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Architecture hyperparameters."""

    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    seq: int
    d_ff: int

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


PRESETS = {
    "transformer": TransformerConfig(
        vocab=64, d_model=64, n_heads=4, n_layers=2, seq=32, d_ff=128
    ),
    "transformer_e2e": TransformerConfig(
        vocab=4096, d_model=384, n_heads=6, n_layers=6, seq=64, d_ff=1536
    ),
    "transformer_100m": TransformerConfig(
        vocab=16384, d_model=768, n_heads=12, n_layers=12, seq=128, d_ff=3072
    ),
}


def _init_raw(key, cfg: TransformerConfig):
    keys = jax.random.split(key, 2 + cfg.n_layers)
    sd = (1.0 / cfg.d_model) ** 0.5
    params = [
        uniform_init(keys[0], (cfg.vocab, cfg.d_model), sd),  # tok emb
        uniform_init(keys[1], (cfg.seq, cfg.d_model), sd),  # pos emb
    ]
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + i], 6)
        sff = (1.0 / cfg.d_ff) ** 0.5
        params.extend(
            [
                uniform_init(lk[0], (3 * cfg.d_model, cfg.d_model), sd),  # qkv
                uniform_init(lk[1], (cfg.d_model, cfg.d_model), sd),  # attn out
                jnp.ones((cfg.d_model,), jnp.float32),  # ln1 scale
                uniform_init(lk[2], (cfg.d_ff, cfg.d_model), sd),  # ff in
                uniform_init(lk[3], (cfg.d_model, cfg.d_ff), sff),  # ff out
                jnp.ones((cfg.d_model,), jnp.float32),  # ln2 scale
            ]
        )
    params.append(jnp.ones((cfg.d_model,), jnp.float32))  # final ln
    return tuple(params)


def _rms_norm(x, scale):
    return x * scale * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _block(x, layer_params, cfg: TransformerConfig, mask):
    wqkv, wo, ln1, wff1, wff2, ln2 = layer_params
    b, t, d = x.shape
    h = _rms_norm(x, ln1)
    qkv = h @ wqkv.T  # (B, T, 3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scores = (q @ k.transpose(0, 1, 3, 2)) / (cfg.head_dim**0.5)
    scores = jnp.where(mask, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1) @ v  # (B, H, T, hd)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + attn @ wo.T
    h = _rms_norm(x, ln2)
    x = x + jax.nn.relu(h @ wff1.T) @ wff2.T
    return x


def _forward(params, x, cfg: TransformerConfig):
    tokens = x.astype(jnp.int32)
    b, t = tokens.shape
    tok_emb, pos_emb = params[0], params[1]
    h = tok_emb[tokens] + pos_emb[None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), bool))[None, None, :, :]
    for i in range(cfg.n_layers):
        layer = params[2 + 6 * i : 2 + 6 * (i + 1)]
        h = _block(h, layer, cfg, mask)
    h = _rms_norm(h, params[-1])
    return h @ params[0].T  # tied embedding


def spec(
    preset: str = "transformer",
    batch_size: int = 8,
    eval_batch_size: int = 16,
) -> ModelSpec:
    """A transformer model spec by preset name."""
    cfg = PRESETS[preset]
    return ModelSpec(
        name=preset,
        kind="lm",
        x_dim=cfg.seq,
        y_dim=cfg.seq,
        batch_size=batch_size,
        eval_batch_size=eval_batch_size,
        num_outputs=cfg.vocab,
        init_raw=functools.partial(_init_raw, cfg=cfg),
        loss_fn=lambda p, x, y: cross_entropy_mean(_forward(p, x, cfg), y),
        eval_fn=lambda p, x, y: token_nll_sum(_forward(p, x, cfg), y),
    )


def param_count(preset: str) -> int:
    """Analytic parameter count of a preset."""
    cfg = PRESETS[preset]
    per_layer = (
        3 * cfg.d_model * cfg.d_model
        + cfg.d_model * cfg.d_model
        + cfg.d_model
        + cfg.d_ff * cfg.d_model
        + cfg.d_model * cfg.d_ff
        + cfg.d_model
    )
    return (
        cfg.vocab * cfg.d_model
        + cfg.seq * cfg.d_model
        + cfg.n_layers * per_layer
        + cfg.d_model
    )
