"""LSTM language model — the LSTM/WikiText2 analog (Table 2 row 4).

Single-layer LSTM (lax.scan over time) with tied input embedding size,
next-token softmax over the vocabulary. Tokens arrive as f32 (the Rust
batch layout is model-agnostic) and are cast to int32 for the embedding
lookup.
"""

import jax
import jax.numpy as jnp

from compile.models.common import (
    ModelSpec,
    cross_entropy_mean,
    token_nll_sum,
    uniform_init,
)

VOCAB = 32
EMBED = 32
HIDDEN = 64
SEQ = 16


def _init_raw(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    se = (1.0 / EMBED) ** 0.5
    sh = (1.0 / HIDDEN) ** 0.5
    return (
        uniform_init(k1, (VOCAB, EMBED), se),  # embedding
        uniform_init(k2, (4 * HIDDEN, EMBED + HIDDEN), sh),  # gates W
        jnp.zeros((4 * HIDDEN,), jnp.float32),  # gates b
        uniform_init(k3, (VOCAB, HIDDEN), sh),  # output proj
        uniform_init(k4, (VOCAB,), 0.01),  # output bias
    )


def _forward(params, x):
    """x: (B, SEQ) f32 token ids -> logits (B, SEQ, VOCAB)."""
    emb, wg, bg, wo, bo = params
    tokens = x.astype(jnp.int32)
    inputs = emb[tokens]  # (B, T, E)
    b = inputs.shape[0]
    h0 = jnp.zeros((b, HIDDEN), jnp.float32)
    c0 = jnp.zeros((b, HIDDEN), jnp.float32)

    def cell(carry, x_t):
        h, c = carry
        zcat = jnp.concatenate([x_t, h], axis=-1)
        gates = zcat @ wg.T + bg
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        return (h_new, c_new), h_new

    xs = jnp.swapaxes(inputs, 0, 1)  # (T, B, E)
    _, hs = jax.lax.scan(cell, (h0, c0), xs)
    hs = jnp.swapaxes(hs, 0, 1)  # (B, T, H)
    return hs @ wo.T + bo


def _loss(params, x, y):
    return cross_entropy_mean(_forward(params, x), y)


def _eval(params, x, y):
    return token_nll_sum(_forward(params, x), y)


def spec(batch_size: int = 8, eval_batch_size: int = 32) -> ModelSpec:
    """The `lstm` model spec."""
    return ModelSpec(
        name="lstm",
        kind="lm",
        x_dim=SEQ,
        y_dim=SEQ,
        batch_size=batch_size,
        eval_batch_size=eval_batch_size,
        num_outputs=VOCAB,
        init_raw=_init_raw,
        loss_fn=_loss,
        eval_fn=_eval,
    )
