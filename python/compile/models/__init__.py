"""Model registry: name -> ModelSpec factory."""

from compile.models import cnn, lstm, mlp, transformer

REGISTRY = {
    "mlp": mlp.spec,
    "cnn": cnn.spec,
    "lstm": lstm.spec,
    "transformer": lambda: transformer.spec("transformer"),
    "transformer_e2e": lambda: transformer.spec("transformer_e2e"),
    "transformer_100m": lambda: transformer.spec(
        "transformer_100m", batch_size=2, eval_batch_size=2
    ),
}

# The models `make artifacts` lowers by default (the big transformers
# are lowered on demand: `python -m compile.aot --models transformer_e2e`).
DEFAULT_MODELS = ["mlp", "cnn", "lstm", "transformer"]


def get_spec(name: str):
    """Look up a ModelSpec by registry name."""
    try:
        return REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown model {name!r}; known: {sorted(REGISTRY)}") from None
