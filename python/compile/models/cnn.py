"""Small convnet — the ResNet20/CIFAR10 analog (Table 2 row 1).

Input is a flat 64-wide vector interpreted as an 8x8x1 image; two 3x3
conv+relu stages with 2x2 mean-pooling, then a dense classifier. Small
enough that a worker step through PJRT is sub-millisecond, but it
exercises real conv lowering in the artifacts.
"""

import jax
import jax.numpy as jnp

from compile.models.common import (
    ModelSpec,
    cross_entropy_mean,
    cross_entropy_sum_and_correct,
    uniform_init,
)

SIDE = 8
DIM = SIDE * SIDE
C1 = 8
C2 = 16
CLASSES = 10


def _init_raw(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return (
        uniform_init(k1, (3, 3, 1, C1), (1.0 / 9.0) ** 0.5),
        jnp.zeros((C1,), jnp.float32),
        uniform_init(k2, (3, 3, C1, C2), (1.0 / (9.0 * C1)) ** 0.5),
        jnp.zeros((C2,), jnp.float32),
        uniform_init(k3, (CLASSES, (SIDE // 4) ** 2 * C2), (1.0 / 64.0) ** 0.5),
        jnp.zeros((CLASSES,), jnp.float32),
    )


def _conv(x, w, b):
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jax.nn.relu(out + b)


def _pool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) / 4.0


def _forward(params, x):
    w1, b1, w2, b2, wd, bd = params
    img = x.reshape((-1, SIDE, SIDE, 1))
    h = _pool2(_conv(img, w1, b1))
    h = _pool2(_conv(h, w2, b2))
    flat = h.reshape((h.shape[0], -1))
    return flat @ wd.T + bd


def _loss(params, x, y):
    return cross_entropy_mean(_forward(params, x), y)


def _eval(params, x, y):
    return cross_entropy_sum_and_correct(_forward(params, x), y)


def spec(batch_size: int = 16, eval_batch_size: int = 64) -> ModelSpec:
    """The `cnn` model spec."""
    return ModelSpec(
        name="cnn",
        kind="classification",
        x_dim=DIM,
        y_dim=1,
        batch_size=batch_size,
        eval_batch_size=eval_batch_size,
        num_outputs=CLASSES,
        init_raw=_init_raw,
        loss_fn=_loss,
        eval_fn=_eval,
    )
