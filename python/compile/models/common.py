"""Shared L2 model machinery: flat-parameter packing, loss helpers, and
the ModelSpec protocol every model module implements.

Parameter layout contract with the Rust coordinator: a model's state is
ONE flat f32 vector. Models define their parameters as a *tuple* of
arrays (tuple order = flat order; ``jax.flatten_util.ravel_pytree`` on
tuples preserves order), and the manifest's ``layer_ranges`` are the
cumulative leaf offsets, so Rust-side per-tensor variance tracking and
LARS address the same slices Python defined.
"""

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Everything `model.py` needs to assemble init/step/eval functions.

    Attributes:
      name: artifact directory name.
      kind: "classification" or "lm".
      x_dim: feature width (seq_len for LM; tokens arrive as f32).
      y_dim: target width (1 for classification, seq_len for LM).
      batch_size: training batch rows.
      eval_batch_size: eval batch rows.
      num_outputs: classes, or vocab size for LM.
      init_raw: PRNGKey -> params pytree (a tuple of arrays).
      loss_fn: (params_pytree, x, y) -> scalar mean loss.
      eval_fn: (params_pytree, x, y) -> (loss_sum, metric_sum).
      weight_decay: decoupled L2 folded into the fused update.
    """

    name: str
    kind: str
    x_dim: int
    y_dim: int
    batch_size: int
    eval_batch_size: int
    num_outputs: int
    init_raw: Callable
    loss_fn: Callable
    eval_fn: Callable
    weight_decay: float = 0.0


def flatten_info(spec: ModelSpec):
    """(param_count, layer_ranges, unravel) for a spec's parameters."""
    params = spec.init_raw(jax.random.PRNGKey(0))
    flat, unravel = ravel_pytree(params)
    ranges = []
    off = 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.size
        ranges.append((off, off + n))
        off += n
    assert off == flat.shape[0]
    return int(flat.shape[0]), ranges, unravel


def cross_entropy_mean(logits, y):
    """Mean softmax cross-entropy; y: int class labels, last-dim logits."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def cross_entropy_sum_and_correct(logits, y):
    """(sum CE, count of argmax==y) over all leading dims."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return -jnp.sum(picked), correct


def token_nll_sum(logits, y):
    """(sum token NLL, token count) for LM eval."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -jnp.sum(picked), jnp.asarray(picked.size, jnp.float32)


def uniform_init(key, shape, scale):
    """U(-scale, scale) f32 initializer (matches the Rust surrogates)."""
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale)
