"""MLP classifier — the DenseNet100/CIFAR10 analog (Table 2 row 3).

Architecture and flat layout deliberately mirror the Rust surrogate
``MlpClassifier`` (``W1(h x d) | b1(h) | W2(c x h) | b2(c)``, tanh
hidden, mean softmax CE), so the Rust integration test can check that
one HLO step equals the surrogate's analytic step on identical inputs.
"""

import jax
import jax.numpy as jnp

from compile.models.common import (
    ModelSpec,
    cross_entropy_mean,
    cross_entropy_sum_and_correct,
    uniform_init,
)

DIM = 32
HIDDEN = 64
CLASSES = 10


def _init_raw(key, dim=DIM, hidden=HIDDEN, classes=CLASSES):
    k1, k2 = jax.random.split(key)
    s1 = (1.0 / dim) ** 0.5
    s2 = (1.0 / hidden) ** 0.5
    return (
        uniform_init(k1, (hidden, dim), s1),
        jnp.zeros((hidden,), jnp.float32),
        uniform_init(k2, (classes, hidden), s2),
        jnp.zeros((classes,), jnp.float32),
    )


def _forward(params, x):
    w1, b1, w2, b2 = params
    h = jnp.tanh(x @ w1.T + b1)
    return h @ w2.T + b2


def _loss(params, x, y):
    return cross_entropy_mean(_forward(params, x), y)


def _eval(params, x, y):
    return cross_entropy_sum_and_correct(_forward(params, x), y)


def spec(batch_size: int = 16, eval_batch_size: int = 64) -> ModelSpec:
    """The `mlp` model spec."""
    return ModelSpec(
        name="mlp",
        kind="classification",
        x_dim=DIM,
        y_dim=1,
        batch_size=batch_size,
        eval_batch_size=eval_batch_size,
        num_outputs=CLASSES,
        init_raw=_init_raw,
        loss_fn=_loss,
        eval_fn=_eval,
    )
