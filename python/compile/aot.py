"""AOT lowering: JAX functions -> HLO *text* artifacts for the Rust
PJRT runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the runtime's xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly.

Usage (from `python/`):
    python -m compile.aot --out-dir ../artifacts
    python -m compile.aot --out-dir ../artifacts --models mlp,transformer_e2e
    python -m compile.aot --out-dir ../artifacts --gossip-ns 4,8,16,32
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as model_lib
from compile.kernels import gossip_mix
from compile.models import DEFAULT_MODELS, get_spec

# Replica counts the gossip kernel is lowered for (one artifact per
# (n, param_count) pair; n <= 128 keeps W in one MXU tile).
DEFAULT_GOSSIP_NS = [4, 8, 16, 32]


def to_hlo_text(fn, example_args) -> str:
    """Lower a function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e3:.1f} kB)")


def lower_model(name: str, out_dir: str) -> int:
    """Lower one model's init/step/eval + manifest. Returns param count."""
    print(f"model {name}:")
    spec = get_spec(name)
    init_fn, step_fn, eval_fn, manifest = model_lib.build_functions(spec)
    args = model_lib.example_args(spec, manifest["param_count"])
    mdir = os.path.join(out_dir, name)
    write(os.path.join(mdir, "init.hlo.txt"), to_hlo_text(init_fn, args["init"]))
    write(os.path.join(mdir, "step.hlo.txt"), to_hlo_text(step_fn, args["step"]))
    write(os.path.join(mdir, "eval.hlo.txt"), to_hlo_text(eval_fn, args["eval"]))
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"  manifest: {manifest['param_count']} params, kind {manifest['kind']}")
    return manifest["param_count"]


def lower_gossip(ns, param_counts, out_dir: str):
    """Lower the gossip_mix kernel for every (n, p) pair."""
    gdir = os.path.join(out_dir, "gossip")
    variants = []
    for n in ns:
        for p in sorted(set(param_counts)):
            f32 = jnp.float32
            w = jax.ShapeDtypeStruct((n, n), f32)
            theta = jax.ShapeDtypeStruct((n, p), f32)
            text = to_hlo_text(lambda w, t: (gossip_mix(w, t),), (w, theta))
            write(os.path.join(gdir, f"mix_n{n}_p{p}.hlo.txt"), text)
            variants.append([n, p])
    with open(os.path.join(gdir, "manifest.json"), "w") as f:
        json.dump({"variants": variants}, f)
    print(f"gossip: {len(variants)} variants")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default=",".join(DEFAULT_MODELS),
        help="comma-separated registry names",
    )
    ap.add_argument(
        "--gossip-ns",
        default=",".join(str(n) for n in DEFAULT_GOSSIP_NS),
        help="replica counts to lower gossip kernels for ('' = skip)",
    )
    args = ap.parse_args()

    models = [m for m in args.models.split(",") if m]
    param_counts = []
    for name in models:
        param_counts.append(lower_model(name, args.out_dir))

    if args.gossip_ns:
        ns = [int(x) for x in args.gossip_ns.split(",")]
        # Gossip kernels sized for the *small* models (the ones the
        # mixed-path benches use); giant transformers mix natively.
        small = [p for p in param_counts if p <= 2_000_000]
        if small:
            lower_gossip(ns, small, args.out_dir)

    print("AOT done.")


if __name__ == "__main__":
    main()
