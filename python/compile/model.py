"""L2 assembly: turn a ModelSpec into the three jitted functions the
Rust coordinator executes (`init`, `step`, `eval`), all over the flat
f32 parameter layout, with the L1 `fused_sgd` Pallas kernel performing
the parameter update *inside* `step` — one PJRT call per worker per
iteration, fwd + bwd + update fused into a single executable.
"""

import jax
import jax.numpy as jnp

from compile.kernels import fused_sgd
from compile.models.common import ModelSpec, flatten_info


def build_functions(spec: ModelSpec):
    """Returns ``(init_fn, step_fn, eval_fn, manifest_dict)``.

    Signatures (matching `rust/src/runtime/bundle.rs`):
      * ``init(seed: i32[]) -> (flat_params: f32[P],)``
      * ``step(params: f32[P], x: f32[B,D], y: i32[...], lr: f32[])
         -> (params': f32[P], loss: f32[])``
      * ``eval(params: f32[P], x: f32[Be,D], y: i32[...])
         -> (loss_sum: f32[], metric_sum: f32[])``
    """
    param_count, layer_ranges, unravel = flatten_info(spec)

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        params = spec.init_raw(key)
        flat, _ = jax.flatten_util.ravel_pytree(params)
        return (flat,)

    def loss_flat(flat, x, y):
        return spec.loss_fn(unravel(flat), x, y)

    def step_fn(flat, x, y, lr):
        loss, grads = jax.value_and_grad(loss_flat)(flat, x, y)
        new_flat = fused_sgd(flat, grads, lr, weight_decay=spec.weight_decay)
        return new_flat, loss

    def eval_fn(flat, x, y):
        loss_sum, metric_sum = spec.eval_fn(unravel(flat), x, y)
        return loss_sum, metric_sum

    manifest = {
        "name": spec.name,
        "kind": spec.kind,
        "param_count": param_count,
        "x_dim": spec.x_dim,
        "y_dim": spec.y_dim,
        "batch_size": spec.batch_size,
        "eval_batch_size": spec.eval_batch_size,
        "num_outputs": spec.num_outputs,
        "layer_ranges": [list(r) for r in layer_ranges],
        "files": {
            "init": "init.hlo.txt",
            "step": "step.hlo.txt",
            "eval": "eval.hlo.txt",
        },
    }
    return init_fn, step_fn, eval_fn, manifest


def example_args(spec: ModelSpec, param_count: int):
    """ShapeDtypeStructs for lowering each function."""
    f32, i32 = jnp.float32, jnp.int32
    p = jax.ShapeDtypeStruct((param_count,), f32)
    x_tr = jax.ShapeDtypeStruct((spec.batch_size, spec.x_dim), f32)
    x_ev = jax.ShapeDtypeStruct((spec.eval_batch_size, spec.x_dim), f32)
    if spec.y_dim == 1:
        y_tr = jax.ShapeDtypeStruct((spec.batch_size,), i32)
        y_ev = jax.ShapeDtypeStruct((spec.eval_batch_size,), i32)
    else:
        y_tr = jax.ShapeDtypeStruct((spec.batch_size, spec.y_dim), i32)
        y_ev = jax.ShapeDtypeStruct((spec.eval_batch_size, spec.y_dim), i32)
    lr = jax.ShapeDtypeStruct((), f32)
    seed = jax.ShapeDtypeStruct((), i32)
    return {
        "init": (seed,),
        "step": (p, x_tr, y_tr, lr),
        "eval": (p, x_ev, y_ev),
    }
