"""L1 Pallas kernels: the compute hot-spots of the decentralized
training stack, written against the TPU-shaped Pallas model and lowered
(interpret=True) into the same HLO artifacts as the L2 models.

- ``gossip_mix``: the paper's neighbor-averaging step as a mixing matmul
  ``Theta' = W @ Theta`` (DESIGN.md §Hardware-Adaptation).
- ``fused_sgd``: single-pass parameter update ``p' = p - lr * g``.
- ``ref``: pure-jnp oracles used by pytest.
"""

from compile.kernels.fused_sgd import fused_sgd
from compile.kernels.gossip_mix import gossip_mix, vmem_report

__all__ = ["fused_sgd", "gossip_mix", "vmem_report"]
