"""Pure-jnp oracles for the L1 kernels (pytest compares against these)."""

import jax.numpy as jnp


def gossip_mix_ref(w, theta):
    """Reference mixing: plain dense matmul."""
    return jnp.dot(w, theta)


def fused_sgd_ref(params, grads, lr, weight_decay: float = 0.0):
    """Reference SGD update."""
    return params - lr * (grads + weight_decay * params)
