"""Fused SGD update as a Pallas kernel: ``p' = p - lr * (g + wd * p)``.

A trivial computation with a non-trivial point: an unfused update reads
``p`` and ``g`` from HBM, writes a temporary for the weight-decay term,
and writes ``p'`` — three HBM round-trips for a memory-bound op. The
fused single-pass kernel performs one read of each operand and one
write, which is the roofline for this op. Called from every L2 model's
``step`` function, so it lowers into each model's ``step.hlo.txt``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D tile: 8192 f32 = 32 KiB per operand per grid step.
TILE = 8192

# Cap on grid steps. Interpret-mode lowers the grid to an XLA while loop
# whose body dynamic-update-slices the output buffer — per-step cost is
# O(P), so an uncapped grid is O(P²/tile) per update (§Perf L1 iteration
# 2: at P = 12.2M the 1492-step grid made one model step take tens of
# seconds; capping at 64 steps keeps the interpret path linear while the
# implied per-step VMEM stays ≤ ~5 MB for ResNet50-scale models on real
# hardware: 3 operands × P/64 × 4 B).
MAX_GRID_STEPS = 64


def _sgd_kernel(lr_ref, wd_ref, p_ref, g_ref, out_ref):
    lr = lr_ref[0]
    wd = wd_ref[0]
    p = p_ref[...]
    out_ref[...] = p - lr * (g_ref[...] + wd * p)


@functools.partial(jax.jit, static_argnames=("tile", "weight_decay"))
def fused_sgd(params, grads, lr, weight_decay: float = 0.0, tile: int = TILE):
    """Single-pass SGD update over flat f32 vectors.

    Args:
      params: ``(p,)`` f32 flat parameters.
      grads: ``(p,)`` f32 flat gradients.
      lr: scalar f32 learning rate (traced — one artifact serves every
        schedule).
      weight_decay: static decoupled L2 coefficient.
      tile: static 1-D block width.

    Returns:
      ``(p,)`` f32 updated parameters.
    """
    (p,) = params.shape
    if grads.shape != (p,):
        raise ValueError(f"grads must be ({p},), got {grads.shape}")
    # Grow the tile so the grid never exceeds MAX_GRID_STEPS.
    t = min(max(tile, -(-p // MAX_GRID_STEPS)), p)
    pad = (t - p % t) % t
    params_p = jnp.pad(params, (0, pad))
    grads_p = jnp.pad(grads, (0, pad))
    lr_arr = jnp.reshape(jnp.asarray(lr, jnp.float32), (1,))
    wd_arr = jnp.full((1,), weight_decay, jnp.float32)
    grid = (params_p.shape[0] // t,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lr (scalar, resident)
            pl.BlockSpec((1,), lambda i: (0,)),  # wd (scalar, resident)
            pl.BlockSpec((t,), lambda i: (i,)),  # params stream
            pl.BlockSpec((t,), lambda i: (i,)),  # grads stream
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(params_p.shape, jnp.float32),
        interpret=True,
    )(lr_arr, wd_arr, params_p, grads_p)
    return out[:p]
