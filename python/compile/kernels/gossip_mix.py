"""The gossip averaging step as a Pallas kernel: ``Theta' = W @ Theta``.

The paper's communication hot-spot — each GPU averaging parameter
tensors with its graph neighbors (``sum_j E_ij theta_j``, §2.2) — maps
onto TPU-shaped hardware as a *mixing matmul*: ``W`` is the dense
``n x n`` mixing matrix (sparsity of the graph encoded as zeros) and
``Theta`` stacks the ``n`` replicas' flat parameters as an ``n x P``
matrix. For ``n <= 128`` all of ``W`` fits in a single MXU tile, so the
kernel keeps ``W`` resident in VMEM and streams ``Theta`` through it in
``TILE_P``-wide column blocks (the BlockSpec grid replaces the paper's
per-link message chunking).

VMEM budget per grid step (f32): ``n*n + 2 * n * TILE_P`` words. With
``n = 64`` and ``TILE_P = 4096`` that is 16 KiB + 2 MiB — comfortably
double-bufferable inside a 16 MiB VMEM (see EXPERIMENTS.md §Perf for
the full table).

Lowered with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, so the kernel lowers to plain HLO (a while-loop
over the grid) — numerically identical, structurally the same schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Column-block width for streaming Theta through VMEM. The §Perf tile
# sweep (EXPERIMENTS.md) picks the largest block whose double-buffered
# footprint still fits a 16 MiB VMEM at n = 64: 8192 f32 columns
# (2 × 4.02 MiB), cutting grid steps 4× vs the 2048 starting point.
TILE_P = 8192


def _mix_kernel(w_ref, theta_ref, out_ref):
    """One grid step: out[:, tile] = W @ theta[:, tile] (MXU matmul)."""
    out_ref[...] = jnp.dot(
        w_ref[...], theta_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_p",))
def gossip_mix(w, theta, tile_p: int = TILE_P):
    """Mix replica parameters: ``theta' = w @ theta``.

    Args:
      w: ``(n, n)`` f32 mixing matrix (rows sum to 1).
      theta: ``(n, p)`` f32 stacked replica parameters.
      tile_p: column-block width (static).

    Returns:
      ``(n, p)`` f32 mixed parameters.
    """
    n, p = theta.shape
    if w.shape != (n, n):
        raise ValueError(f"w must be ({n},{n}), got {w.shape}")
    tile = min(tile_p, p)
    # Pad P to a tile multiple; padded columns are zeros and mix to zero.
    p_pad = (tile - p % tile) % tile
    theta_padded = jnp.pad(theta, ((0, 0), (0, p_pad)))
    grid = (theta_padded.shape[1] // tile,)
    out = pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),  # W resident
            pl.BlockSpec((n, tile), lambda j: (0, j)),  # stream Theta
        ],
        out_specs=pl.BlockSpec((n, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(theta_padded.shape, jnp.float32),
        interpret=True,
    )(w, theta_padded)
    return out[:, :p]


def vmem_report(n: int, p: int, tile_p: int = TILE_P) -> dict:
    """Analytic VMEM/MXU estimate for a (n, p) mixing call — the L1
    profile used in EXPERIMENTS.md §Perf (interpret=True gives no real
    TPU timings, so the kernel is profiled structurally)."""
    tile = min(tile_p, p)
    vmem_words = n * n + 2 * n * tile
    grid_steps = -(-p // tile)
    flops = 2 * n * n * p  # dense mixing matmul
    # MXU does 128x128 f32-accumulate tiles; utilization is the fraction
    # of each 128-lane tile actually filled by n rows.
    mxu_fill = min(n, 128) / 128.0
    return {
        "n": n,
        "p": p,
        "tile_p": tile,
        "vmem_bytes": vmem_words * 4,
        "grid_steps": grid_steps,
        "flops": flops,
        "mxu_row_fill": mxu_fill,
    }
